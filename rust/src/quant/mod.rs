//! Int8 quantization substrate (paper §VI-B/§VI-D compares Int8-Dense and
//! Int8-Sparse against the pruning patterns).
//!
//! Symmetric quantization: `q = clamp(round(x / scale), -127, 127)` with
//! `scale = max|x| / 127`.  Weights are quantized **per output channel**
//! (one scale per output column of the `K x N` operand) so a single
//! badly-scaled channel cannot inflate the quantization error of every
//! other column; activations are quantized **dynamically per batch** with
//! one tensor-wide scale (the activation range is not known at pack time).
//! The Int8 GEMM accumulates in i32 and dequantizes on store:
//! `c[i][j] = acc_i32 * a_scale * w_scales[j]`.
//!
//! The paper's survey claim ("Int8 exhibits almost no accuracy loss") is
//! validated on the accuracy surrogate (see `accuracy/`), and the serving
//! kernels built on this substrate live in `gemm::int8`.

use crate::tensor::Matrix;

/// Largest reduction depth the i32 accumulator provably survives: every
/// product is at most `127 * 127 = 16129 < 2^14`, so `K <= 2^16` keeps the
/// running sum below `2^30 < i32::MAX` even when every term has the same
/// sign at the worst-case magnitude.  `QuantMatrix::quantize` debug-asserts
/// this bound; no model in the zoo comes within two orders of magnitude of
/// it.
pub const I32_ACC_SAFE_K: usize = 1 << 16;

/// Numeric precision of a packed GEMM node / a compiled graph.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    /// The f32 kernels (PRs 2-8): the baseline serving path.
    #[default]
    Fp32,
    /// i8 x i8 -> i32 kernels with dequantization on store.
    Int8,
    /// Defer to the plan cache's per-shape recommendation (falls back to
    /// f32 for shapes the tuner has not measured).
    Auto,
}

impl Precision {
    /// Stable text form, used by the plan cache and the serve CLI.
    pub fn label(&self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Int8 => "int8",
            Precision::Auto => "auto",
        }
    }

    /// Inverse of [`Precision::label`].
    pub fn from_label(s: &str) -> Option<Precision> {
        match s {
            "fp32" => Some(Precision::Fp32),
            "int8" => Some(Precision::Int8),
            "auto" => Some(Precision::Auto),
            _ => None,
        }
    }
}

/// A symmetric Int8 quantized `K x N` weight matrix with **per-output-
/// channel** scales (`scales[c]` covers column `c`).
#[derive(Clone, Debug)]
pub struct QuantMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Row-major `rows x cols` quantized values.
    pub data: Vec<i8>,
    /// One scale per output column; all-zero columns get scale 1.0 so
    /// dequantization never multiplies by a degenerate (zero) scale.
    pub scales: Vec<f32>,
}

impl QuantMatrix {
    /// Quantize per output channel with `scales[c] = max|x[:, c]| / 127`
    /// (symmetric, zero-point 0).  All-zero channels take scale 1.0: their
    /// quantized values are exactly 0, and a 1.0 scale keeps
    /// `dequantize`/`error_bound` well-defined instead of propagating a
    /// degenerate 0 (or NaN-producing) scale downstream.
    ///
    /// The i32 GEMM accumulator is provably overflow-free only while the
    /// reduction depth stays within [`I32_ACC_SAFE_K`] (worst case
    /// `K * 127 * 127 < 2^31`); quantizing a weight deeper than that is a
    /// caller bug.
    pub fn quantize(x: &Matrix) -> QuantMatrix {
        debug_assert!(
            x.rows <= I32_ACC_SAFE_K,
            "K={} exceeds the i32 accumulator safety bound {} (127*127*K would overflow)",
            x.rows,
            I32_ACC_SAFE_K
        );
        let (rows, cols) = (x.rows, x.cols);
        let mut scales = vec![1.0f32; cols];
        for (c, s) in scales.iter_mut().enumerate() {
            let mut amax = 0.0f32;
            for r in 0..rows {
                amax = amax.max(x.data[r * cols + c].abs());
            }
            if amax > 0.0 {
                *s = amax / 127.0;
            }
        }
        let mut data = vec![0i8; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                let q = (x.data[r * cols + c] / scales[c]).round().clamp(-127.0, 127.0);
                data[r * cols + c] = q as i8;
            }
        }
        QuantMatrix { rows, cols, data, scales }
    }

    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[r * self.cols + c] =
                    self.data[r * self.cols + c] as f32 * self.scales[c];
            }
        }
        out
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i8 {
        self.data[r * self.cols + c]
    }

    /// Worst-case element quantization error of column `c`: scale / 2
    /// (round-to-nearest halves the quantization step).
    pub fn error_bound(&self, c: usize) -> f32 {
        self.scales[c] * 0.5
    }

    /// The loosest per-channel bound — a whole-matrix tolerance.
    pub fn max_error_bound(&self) -> f32 {
        self.scales.iter().fold(0.0f32, |a, &s| a.max(s)) * 0.5
    }

    /// Bytes of the quantized representation (values + scales).
    pub fn storage_bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }
}

/// Dynamic per-batch activation quantization: one symmetric tensor-wide
/// scale over `src`, quantized values written into `dst[..src.len()]`
/// (the caller stages `dst` in the workspace `GemmScratch` — no
/// per-request allocation).  Returns the scale; all-zero batches get
/// scale 1.0 like all-zero weight channels.
pub fn quantize_activations_into(src: &[f32], dst: &mut [i8]) -> f32 {
    debug_assert!(dst.len() >= src.len());
    let amax = src.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
    let inv = 1.0 / scale;
    for (d, &v) in dst.iter_mut().zip(src) {
        *d = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// Reference Int8 GEMM (the scalar oracle for the SIMD kernels in
/// `gemm::int8`): dynamically quantizes `a`, accumulates in i32, and
/// dequantizes on store via `a_scale * w.scales[j]`.
pub fn int8_matmul(a: &Matrix, w: &QuantMatrix) -> Matrix {
    assert_eq!(a.cols, w.rows);
    let (m, k, n) = (a.rows, a.cols, w.cols);
    let mut qa = vec![0i8; m * k];
    let a_scale = quantize_activations_into(&a.data, &mut qa);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = &qa[i * k..(i + 1) * k];
        let mut acc = vec![0i32; n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0 {
                continue;
            }
            let brow = &w.data[kk * n..(kk + 1) * n];
            let aik = aik as i32;
            for (av, bv) in acc.iter_mut().zip(brow) {
                *av += aik * *bv as i32;
            }
        }
        for (j, (cv, av)) in c.row_mut(i).iter_mut().zip(&acc).enumerate() {
            *cv = *av as f32 * a_scale * w.scales[j];
        }
    }
    c
}

/// Int8 + 2:4 sparse storage (the "Int8-Sparse" configuration): B is
/// 2:4-compressed Int8 values + positions, with per-output-channel scales.
#[derive(Clone, Debug)]
pub struct QuantVw24 {
    pub k: usize,
    pub n: usize,
    pub vals: Vec<i8>,
    pub sel: Vec<u8>,
    pub scales: Vec<f32>,
}

impl QuantVw24 {
    /// Quantize per channel then 2:4-compress along K (keep top-2
    /// magnitudes per 4-group).
    pub fn from_dense(w: &Matrix) -> QuantVw24 {
        assert_eq!(w.rows % 4, 0);
        let q = QuantMatrix::quantize(w);
        let (k, n) = (w.rows, w.cols);
        let khalf = k / 2;
        let mut vals = vec![0i8; khalf * n];
        let mut sel = vec![0u8; khalf * n];
        for c in 0..n {
            for grp in 0..k / 4 {
                let mut idx: Vec<usize> = (0..4).collect();
                idx.sort_by_key(|&i| std::cmp::Reverse((q.at(grp * 4 + i, c) as i32).abs()));
                let mut keep = [idx[0], idx[1]];
                keep.sort_unstable();
                for (slot, &pos) in keep.iter().enumerate() {
                    vals[(grp * 2 + slot) * n + c] = q.at(grp * 4 + pos, c);
                    sel[(grp * 2 + slot) * n + c] = pos as u8;
                }
            }
        }
        QuantVw24 { k, n, vals, sel, scales: q.scales }
    }
}

/// C = A_q * B_q24 with i32 accumulation (sparse-tensor-core Int8 path);
/// `a` is dynamically quantized like [`int8_matmul`].
pub fn int8_vw24_matmul(a: &Matrix, b: &QuantVw24) -> Matrix {
    assert_eq!(a.cols, b.k);
    let (m, n) = (a.rows, b.n);
    let khalf = b.k / 2;
    let mut qa = vec![0i8; m * a.cols];
    let a_scale = quantize_activations_into(&a.data, &mut qa);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = &qa[i * a.cols..(i + 1) * a.cols];
        let mut acc = vec![0i32; n];
        for ii in 0..khalf {
            let grp_base = (ii / 2) * 4;
            let vrow = &b.vals[ii * n..(ii + 1) * n];
            let srow = &b.sel[ii * n..(ii + 1) * n];
            for j in 0..n {
                let r = grp_base + srow[j] as usize;
                acc[j] += arow[r] as i32 * vrow[j] as i32;
            }
        }
        for (j, (cv, av)) in c.row_mut(i).iter_mut().zip(&acc).enumerate() {
            *cv = *av as f32 * a_scale * b.scales[j];
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::matmul_naive;
    use crate::util::Rng;

    #[test]
    fn quantize_roundtrip_error_bounded_per_channel() {
        let mut rng = Rng::new(1);
        let mut x = Matrix::randn(32, 32, &mut rng);
        // one deliberately tiny-range channel: per-channel scales keep its
        // roundtrip error proportional to *its* range, not the matrix max
        for r in 0..32 {
            x.data[r * 32 + 5] *= 1e-3;
        }
        let q = QuantMatrix::quantize(&x);
        let back = q.dequantize();
        for c in 0..32 {
            let bound = q.error_bound(c) + 1e-6;
            for r in 0..32 {
                let err = (x.at(r, c) - back.at(r, c)).abs();
                assert!(err <= bound, "col {c}: err {err} > bound {bound}");
            }
        }
        assert!(q.error_bound(5) < q.error_bound(0) * 1e-2, "tiny channel gets a tiny scale");
    }

    #[test]
    fn int8_matmul_close_to_fp32() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(24, 48, &mut rng);
        let b = Matrix::randn(48, 32, &mut rng);
        let c_fp = matmul_naive(&a, &b);
        let c_q = int8_matmul(&a, &QuantMatrix::quantize(&b));
        // relative Frobenius error small (the "almost no accuracy loss" claim)
        let rel = c_q.dist(&c_fp) / c_fp.dist(&Matrix::zeros(24, 32)).max(1e-9);
        assert!(rel < 0.03, "relative error {rel}");
    }

    #[test]
    fn int8_vw24_matches_dequantized_sparse() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(16, 32, &mut rng);
        let w = Matrix::randn(32, 24, &mut rng);
        let wq24 = QuantVw24::from_dense(&w);
        let got = int8_vw24_matmul(&a, &wq24);
        // reference: dequantize the kept values and run fp GEMM on the
        // dequantized activations
        let khalf = wq24.k / 2;
        let mut wd = Matrix::zeros(wq24.k, wq24.n);
        for c in 0..wq24.n {
            for ii in 0..khalf {
                let r = (ii / 2) * 4 + wq24.sel[ii * wq24.n + c] as usize;
                *wd.at_mut(r, c) = wq24.vals[ii * wq24.n + c] as f32 * wq24.scales[c];
            }
        }
        let mut qa = vec![0i8; a.data.len()];
        let a_scale = quantize_activations_into(&a.data, &mut qa);
        let ad = Matrix::from_vec(
            a.rows,
            a.cols,
            qa.iter().map(|&q| q as f32 * a_scale).collect(),
        );
        let want = matmul_naive(&ad, &wd);
        assert!(got.max_abs_diff(&want) < 1e-3, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn zero_matrix_and_zero_channels_quantize_with_unit_scale() {
        let z = Matrix::zeros(4, 4);
        let q = QuantMatrix::quantize(&z);
        assert!(q.data.iter().all(|&v| v == 0));
        assert!(q.scales.iter().all(|&s| s == 1.0), "all-zero channel keeps scale 1.0");
        assert_eq!(q.dequantize(), z);
        // mixed: one live channel, three zero ones
        let mut x = Matrix::zeros(4, 4);
        for r in 0..4 {
            x.data[r * 4 + 2] = (r as f32 + 1.0) * 0.25;
        }
        let q = QuantMatrix::quantize(&x);
        assert_eq!(q.scales[0], 1.0);
        assert_eq!(q.scales[3], 1.0);
        assert!((q.dequantize().max_abs_diff(&x)) <= q.error_bound(2) + 1e-6);
    }

    #[test]
    fn activation_quantization_is_dynamic_and_bounded() {
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..257).map(|_| (rng.next_f32() - 0.5) * 3.0).collect();
        let mut q = vec![0i8; 257];
        let scale = quantize_activations_into(&x, &mut q);
        for (&v, &qv) in x.iter().zip(&q) {
            assert!((v - qv as f32 * scale).abs() <= scale * 0.5 + 1e-6);
        }
        // all-zero batch: unit scale, zero codes
        let scale = quantize_activations_into(&[0.0; 8], &mut q);
        assert_eq!(scale, 1.0);
        assert!(q[..8].iter().all(|&v| v == 0));
    }

    #[test]
    fn storage_is_quarter_of_fp32() {
        // Int8 value storage = 1 byte/elem vs 4 for f32
        let mut rng = Rng::new(4);
        let x = Matrix::randn(64, 64, &mut rng);
        let q = QuantMatrix::quantize(&x);
        assert_eq!(q.data.len(), x.data.len());
        assert_eq!(std::mem::size_of_val(&q.data[..]) * 4, std::mem::size_of_val(&x.data[..]));
        assert!(q.storage_bytes() < x.data.len() * 4 / 3);
    }

    #[test]
    fn precision_labels_roundtrip() {
        for p in [Precision::Fp32, Precision::Int8, Precision::Auto] {
            assert_eq!(Precision::from_label(p.label()), Some(p));
        }
        assert!(Precision::from_label("f16").is_none());
        assert_eq!(Precision::default(), Precision::Fp32);
    }
}
