//! Int8 quantization substrate (paper §VI-B/§VI-D compares Int8-Dense and
//! Int8-Sparse against the pruning patterns).
//!
//! Symmetric per-tensor quantization: `q = clamp(round(x / scale), -127,
//! 127)` with `scale = max|x| / 127`, plus an Int8 GEMM with i32
//! accumulation and float dequantization — the arithmetic the tensor
//! core's Int8 path performs.  The paper's survey claim ("Int8 exhibits
//! almost no accuracy loss") is validated on the accuracy proxy.

use crate::tensor::Matrix;

/// A symmetric per-tensor Int8 quantized matrix.
#[derive(Clone, Debug)]
pub struct QuantMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
    pub scale: f32,
}

impl QuantMatrix {
    /// Quantize with scale = max|x| / 127 (symmetric, zero-point 0).
    pub fn quantize(x: &Matrix) -> QuantMatrix {
        let amax = x.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
        let data = x
            .data
            .iter()
            .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
            .collect();
        QuantMatrix { rows: x.rows, cols: x.cols, data, scale }
    }

    pub fn dequantize(&self) -> Matrix {
        Matrix::from_vec(
            self.rows,
            self.cols,
            self.data.iter().map(|&q| q as f32 * self.scale).collect(),
        )
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i8 {
        self.data[r * self.cols + c]
    }

    /// Worst-case element quantization error bound: scale / 2.
    pub fn error_bound(&self) -> f32 {
        self.scale * 0.5
    }
}

/// Int8 GEMM with i32 accumulation, dequantized to f32 on output — the
/// tensor-core Int8 data path.
pub fn int8_matmul(a: &QuantMatrix, b: &QuantMatrix) -> Matrix {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    let out_scale = a.scale * b.scale;
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = c.row_mut(i);
        let mut acc = vec![0i32; n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0 {
                continue;
            }
            let brow = &b.data[kk * n..(kk + 1) * n];
            let aik = aik as i32;
            for (av, bv) in acc.iter_mut().zip(brow) {
                *av += aik * *bv as i32;
            }
        }
        for (cv, av) in crow.iter_mut().zip(&acc) {
            *cv = *av as f32 * out_scale;
        }
    }
    c
}

/// Int8 + 2:4 sparse GEMM (the "Int8-Sparse" configuration): B is
/// 2:4-compressed Int8 values + positions.
#[derive(Clone, Debug)]
pub struct QuantVw24 {
    pub k: usize,
    pub n: usize,
    pub vals: Vec<i8>,
    pub sel: Vec<u8>,
    pub scale: f32,
}

impl QuantVw24 {
    /// Quantize then 2:4-compress along K (keep top-2 magnitudes/group).
    pub fn from_dense(w: &Matrix) -> QuantVw24 {
        assert_eq!(w.rows % 4, 0);
        let q = QuantMatrix::quantize(w);
        let (k, n) = (w.rows, w.cols);
        let khalf = k / 2;
        let mut vals = vec![0i8; khalf * n];
        let mut sel = vec![0u8; khalf * n];
        for c in 0..n {
            for grp in 0..k / 4 {
                let mut idx: Vec<usize> = (0..4).collect();
                idx.sort_by_key(|&i| std::cmp::Reverse((q.at(grp * 4 + i, c) as i32).abs()));
                let mut keep = [idx[0], idx[1]];
                keep.sort_unstable();
                for (slot, &pos) in keep.iter().enumerate() {
                    vals[(grp * 2 + slot) * n + c] = q.at(grp * 4 + pos, c);
                    sel[(grp * 2 + slot) * n + c] = pos as u8;
                }
            }
        }
        QuantVw24 { k, n, vals, sel, scale: q.scale }
    }
}

/// C = A_q * B_q24 with i32 accumulation (sparse-tensor-core Int8 path).
pub fn int8_vw24_matmul(a: &QuantMatrix, b: &QuantVw24) -> Matrix {
    assert_eq!(a.cols, b.k);
    let (m, n) = (a.rows, b.n);
    let khalf = b.k / 2;
    let mut c = Matrix::zeros(m, n);
    let out_scale = a.scale * b.scale;
    for i in 0..m {
        let arow = &a.data[i * a.cols..(i + 1) * a.cols];
        let mut acc = vec![0i32; n];
        for ii in 0..khalf {
            let grp_base = (ii / 2) * 4;
            let vrow = &b.vals[ii * n..(ii + 1) * n];
            let srow = &b.sel[ii * n..(ii + 1) * n];
            for j in 0..n {
                let r = grp_base + srow[j] as usize;
                acc[j] += arow[r] as i32 * vrow[j] as i32;
            }
        }
        for (cv, av) in c.row_mut(i).iter_mut().zip(&acc) {
            *cv = *av as f32 * out_scale;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::matmul_naive;
    use crate::util::Rng;

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        let x = Matrix::randn(32, 32, &mut rng);
        let q = QuantMatrix::quantize(&x);
        let back = q.dequantize();
        let err = x.max_abs_diff(&back);
        assert!(err <= q.error_bound() + 1e-6, "err {err} > bound {}", q.error_bound());
    }

    #[test]
    fn int8_matmul_close_to_fp32() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(24, 48, &mut rng);
        let b = Matrix::randn(48, 32, &mut rng);
        let c_fp = matmul_naive(&a, &b);
        let c_q = int8_matmul(&QuantMatrix::quantize(&a), &QuantMatrix::quantize(&b));
        // relative Frobenius error small (the "almost no accuracy loss" claim)
        let rel = c_q.dist(&c_fp) / c_fp.dist(&Matrix::zeros(24, 32)).max(1e-9);
        assert!(rel < 0.03, "relative error {rel}");
    }

    #[test]
    fn int8_vw24_matches_dequantized_sparse() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(16, 32, &mut rng);
        let w = Matrix::randn(32, 24, &mut rng);
        let aq = QuantMatrix::quantize(&a);
        let wq24 = QuantVw24::from_dense(&w);
        let got = int8_vw24_matmul(&aq, &wq24);
        // reference: dequantize the kept values and run fp GEMM
        let khalf = wq24.k / 2;
        let mut wd = Matrix::zeros(wq24.k, wq24.n);
        for c in 0..wq24.n {
            for ii in 0..khalf {
                let r = (ii / 2) * 4 + wq24.sel[ii * wq24.n + c] as usize;
                *wd.at_mut(r, c) = wq24.vals[ii * wq24.n + c] as f32 * wq24.scale;
            }
        }
        let want = matmul_naive(&aq.dequantize(), &wd);
        assert!(got.max_abs_diff(&want) < 1e-3, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn zero_matrix_quantizes() {
        let z = Matrix::zeros(4, 4);
        let q = QuantMatrix::quantize(&z);
        assert!(q.data.iter().all(|&v| v == 0));
        assert_eq!(q.dequantize(), z);
    }

    #[test]
    fn storage_is_quarter_of_fp32() {
        // Int8 value storage = 1 byte/elem vs 4 for f32
        let mut rng = Rng::new(4);
        let x = Matrix::randn(64, 64, &mut rng);
        let q = QuantMatrix::quantize(&x);
        assert_eq!(q.data.len(), x.data.len());
        assert_eq!(std::mem::size_of_val(&q.data[..]) * 4, std::mem::size_of_val(&x.data[..]));
    }
}
