//! Row-major dense matrices — the substrate type shared by the pruner, the
//! CPU GEMM kernels, and the runtime literal conversion.

use crate::util::Rng;

/// Row-major `f32` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    /// Filled with N(0, 1/sqrt(cols_in)) — Xavier-ish, matching the Python
    /// side's `init_params`.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let scale = 1.0 / (rows as f32).sqrt();
        let data = (0..rows * cols).map(|_| rng.normal_f32() * scale).collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Frobenius-norm distance, for approximate comparisons in tests.
    pub fn dist(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt()
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_roundtrip() {
        let mut m = Matrix::zeros(3, 4);
        *m.at_mut(1, 2) = 5.0;
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1)[2], 5.0);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(9);
        let m = Matrix::randn(5, 7, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn dist_zero_for_identical() {
        let mut rng = Rng::new(9);
        let m = Matrix::randn(4, 4, &mut rng);
        assert_eq!(m.dist(&m), 0.0);
    }
}
