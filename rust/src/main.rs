//! `tilewise` CLI — leader entrypoint for the serving stack, the figure
//! harnesses, and the inspection tools.
//!
//! Subcommands (hand-rolled parser; the offline registry has no clap):
//!   serve             run the serving stack with a synthetic open-loop client
//!   profile           per-GEMM-node attribution of the zoo models (Fig. 10 style)
//!   autotune          tune a model zoo entry's GEMMs, write the plan cache
//!   figure <id|all>   regenerate a paper figure (fig6a..fig11, headline)
//!   inspect-patterns  print the Fig. 9 mask heatmaps + statistics
//!   prune             run the multi-stage pruner on a synthetic matrix
//!   simulate          one-off gpusim query (shape x pattern x sparsity)

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use tilewise::autotune::{MeasureOpts, PatternFamily, PlanCache, Tuner, TunerOpts};
use tilewise::coordinator::{start, start_with_backend, BatcherConfig, Policy, ServerConfig};
use tilewise::exec::{Backend, NativeBackend, NativeModelSpec, ZooBackend, ZooSpec};
use tilewise::variant::Variant;
use tilewise::figures::{fig10, fig6, fig7, fig8, fig9, headline};
use tilewise::gpusim::{self, Calibration, GemmShape, Pipe, TwStrategy};
use tilewise::models::{self, ModelWorkload};
use tilewise::quant::Precision;
use tilewise::sparse::Pattern;
use tilewise::telemetry::Telemetry;
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("serve") => cmd_serve(&args[1..]),
        Some("profile") => cmd_profile(&args[1..]),
        Some("autotune") => cmd_autotune(&args[1..]),
        Some("figure") => cmd_figure(&args[1..]),
        Some("inspect-patterns") => cmd_inspect(),
        Some("prune") => cmd_prune(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("simulate-model") => cmd_simulate_model(&args[1..]),
        _ => {
            eprintln!(
                "usage: tilewise <command>\n\
                 \n\
                 commands:\n\
                 \x20 serve [--backend pjrt|native] [--workers N] [--intra-threads N] [--artifacts DIR]\n\
                 \x20       [--requests N] [--rate RPS] [--policy dense|tw|tvw|rr|adaptive|tuned]\n\
                 \x20       [--plan-cache FILE] [--model bert|vgg|nmt|decoder|nano|bert-ffn]\n\
                 \x20       [--precision fp32|int8|auto] [--low-latency] [--padded] [--decode N]\n\
                 \x20       [--no-fusion] [--telemetry-json FILE]\n\
                 \x20       (bert/vgg/nmt/decoder serve the graph-compiled zoo model; nano\n\
                 \x20        the residual-MLP surrogate; bert-ffn the BERT-base FFN widths;\n\
                 \x20        --precision packs zoo weights at f32, int8 (quantize-at-pack),\n\
                 \x20        or the plan cache's tuned choice per layer (auto);\n\
                 \x20        --low-latency enables eager dispatch + the M=1 fast lane;\n\
                 \x20        --padded disables dynamic effective-batch execution;\n\
                 \x20        --decode N streams N autoregressive sessions through the\n\
                 \x20        continuous-batching decode lane (nmt|decoder models);\n\
                 \x20        --no-fusion disables graph-level epilogue fusion (also\n\
                 \x20        via PALLAS_NO_FUSION=1) for A/B and parity runs;\n\
                 \x20        --telemetry-json dumps metrics + graph profile periodically)\n\
                 \x20 profile [--model bert|vgg|nmt] [--runs N] [--intra-threads N] [--out FILE]\n\
                 \x20         (per-GEMM-node time/FLOPs attribution across all variants;\n\
                 \x20          default sweeps bert+vgg+nmt into BENCH_profile.json)\n\
                 \x20 autotune [--model vgg16|resnet18|resnet50|nmt|bert] [--sparsity S] [--out FILE]\n\
                 \x20          [--threads T] [--m-cap M] [--budget-ms MS] [--quick]\n\
                 \x20          [--precision fp32|int8]  (pin the precision axis; default searches both)\n\
                 \x20 figure <fig6a|fig6b|fig6c|fig7a|fig7b|fig8|fig9|fig10|fig11|headline|all> [--csv DIR]\n\
                 \x20 inspect-patterns\n\
                 \x20 prune [--pattern ew|vw|bw|tw|tew|tvw] [--sparsity S] [--g G]\n\
                 \x20 simulate [--m M --k K --n N] [--sparsity S] [--g G]\n\
                 \x20 simulate-model [--model vgg16|resnet18|resnet50|nmt|bert] [--sparsity S] [--g G]"
            );
            2
        }
    };
    std::process::exit(code);
}

fn workload_by_name(name: &str) -> Option<ModelWorkload> {
    Some(match name {
        "vgg16" => models::vgg16(),
        "resnet18" => models::resnet18(),
        "resnet50" => models::resnet50(),
        "nmt" => models::nmt(128),
        "bert" => models::bert_base(8, 128),
        _ => return None,
    })
}

fn cmd_autotune(args: &[String]) -> i32 {
    let model = flag(args, "--model").unwrap_or_else(|| "bert".into());
    let sparsity: f64 = flag(args, "--sparsity").and_then(|v| v.parse().ok()).unwrap_or(0.75);
    let out = PathBuf::from(flag(args, "--out").unwrap_or_else(|| "plans.json".into()));
    let threads: usize = flag(args, "--threads").and_then(|v| v.parse().ok()).unwrap_or_else(|| {
        std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1)
    });
    let m_cap: usize = flag(args, "--m-cap").and_then(|v| v.parse().ok()).unwrap_or(256);
    let quick = args.iter().any(|a| a == "--quick");

    let Some(workload) = workload_by_name(&model) else {
        eprintln!("unknown model {model:?} (expected vgg16|resnet18|resnet50|nmt|bert)");
        return 2;
    };
    let mut opts = TunerOpts {
        sparsity,
        nthreads: threads,
        m_cap: Some(m_cap),
        ..TunerOpts::default()
    };
    opts.measure = if quick { MeasureOpts::quick() } else { MeasureOpts::default() };
    if let Some(ms) = flag(args, "--budget-ms").and_then(|v| v.parse::<f64>().ok()) {
        opts.measure.budget_secs = ms / 1e3;
    }
    // --precision pins the search axis to one numeric precision; the
    // default space measures fp32 AND int8 twins of every candidate
    if let Some(v) = flag(args, "--precision") {
        match Precision::from_label(&v) {
            Some(p @ (Precision::Fp32 | Precision::Int8)) => opts.space.precisions = vec![p],
            _ => {
                eprintln!("unknown precision {v:?} (expected fp32|int8)");
                return 2;
            }
        }
    }
    let tuner = Tuner::new(opts);

    println!(
        "autotuning {} ({} prunable layers) @ {:.0}% sparsity, {threads} thread(s), m-cap {m_cap}",
        workload.name,
        workload.prunable_layers().count(),
        sparsity * 100.0
    );
    let families = [PatternFamily::Dense, PatternFamily::Tw, PatternFamily::Tvw];
    let (cache, results) = tuner.tune_workload(&workload, &model, &families);

    println!(
        "{:<22}{:>8}{:>14}{:>12}{:>12}{:>9}   {}",
        "shape(MxKxN)", "family", "default(us)", "tuned(us)", "model(us)", "speedup", "winner"
    );
    for r in &results {
        let e = &r.entry;
        println!(
            "{:<22}{:>8}{:>14.1}{:>12.1}{:>12.1}{:>8.2}x   {}",
            format!("{}x{}x{}", e.key.m, e.key.k, e.key.n),
            e.key.pattern,
            e.default_us,
            e.measured_us,
            e.model_us,
            e.speedup(),
            e.candidate().map(|c| c.label()).unwrap_or_default(),
        );
    }
    if let Some(variant) = cache.model_variant(&model) {
        println!("serving recommendation for {model:?}: {variant}");
    }
    match cache.save(&out) {
        Ok(()) => {
            println!("wrote {} tuned entries to {}", cache.len(), out.display());
            0
        }
        Err(e) => {
            eprintln!("failed to write plan cache: {e}");
            1
        }
    }
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn cmd_serve(args: &[String]) -> i32 {
    let dir = PathBuf::from(flag(args, "--artifacts").unwrap_or_else(|| "artifacts".into()));
    let backend_name = flag(args, "--backend").unwrap_or_else(|| "pjrt".into());
    let workers: usize = flag(args, "--workers").and_then(|v| v.parse().ok()).unwrap_or(1);
    // intra-op kernel lanes of the shared pool (DESIGN.md §5): default
    // serial; size workers + intra_threads - 1 near the core count
    let intra_threads: usize =
        flag(args, "--intra-threads").and_then(|v| v.parse().ok()).unwrap_or(1);
    let requests: usize = flag(args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(64);
    let rate: f64 = flag(args, "--rate").and_then(|v| v.parse().ok()).unwrap_or(50.0);
    let plan_cache = flag(args, "--plan-cache").map(PathBuf::from);
    let telemetry_json = flag(args, "--telemetry-json").map(PathBuf::from);
    let decode_sessions: usize = flag(args, "--decode").and_then(|v| v.parse().ok()).unwrap_or(0);
    let precision = match flag(args, "--precision").as_deref() {
        None => Precision::Fp32,
        Some(v) => match Precision::from_label(v) {
            Some(p) => p,
            None => {
                eprintln!("unknown precision {v:?} (expected fp32|int8|auto)");
                return 2;
            }
        },
    };
    let policy = match flag(args, "--policy").as_deref() {
        Some("dense") => Policy::Fixed(Variant::Dense),
        Some("tvw") => Policy::Fixed(Variant::Tvw),
        Some("rr") => Policy::RoundRobin(vec![Variant::Dense, Variant::Tw, Variant::Tvw]),
        Some("adaptive") => Policy::Adaptive {
            dense: Variant::Dense,
            sparse: Variant::Tvw,
            queue_threshold: 8,
        },
        Some("tuned") => Policy::Tuned {
            // the cache keys recommendations under the autotune CLI's
            // model names; `serve --model vgg` maps to the tuned "vgg16"
            model: match flag(args, "--model").as_deref() {
                Some("vgg") => "vgg16".into(),
                Some(m) => m.into(),
                None => "bert".into(),
            },
            fallback: Variant::Dense,
        },
        // no explicit policy: the native backend round-robins so one run
        // exercises dense/TW/TVW end-to-end; pjrt keeps the TW default
        None if backend_name == "native" => {
            Policy::RoundRobin(vec![Variant::Dense, Variant::Tw, Variant::Tvw])
        }
        _ => Policy::Fixed(Variant::Tw),
    };
    // --low-latency: eager dispatch + the M=1 fast lane; --padded: keep
    // the historical full-B zero-padded execution (dynamic effective-
    // batch is the default)
    let low_latency = args.iter().any(|a| a == "--low-latency");
    let dynamic_batch = !args.iter().any(|a| a == "--padded");
    // --no-fusion: compile without the graph-level epilogue fusion pass
    // (the escape hatch; PALLAS_NO_FUSION=1 reaches the same switch)
    let no_fusion = args.iter().any(|a| a == "--no-fusion");
    let mut builder = ServerConfig::builder()
        .policy(policy)
        .workers(workers)
        .intra_threads(intra_threads)
        .dynamic_batch(dynamic_batch);
    if low_latency {
        builder = builder
            .batcher(BatcherConfig::low_latency(BatcherConfig::default().max_batch))
            .fast_lane(true);
    }
    if let Some(p) = plan_cache.clone() {
        builder = builder.plan_cache(p);
    }
    let mut cfg = match builder.build() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bad serve configuration: {e}");
            return 2;
        }
    };
    let mut native_cache: Option<Arc<PlanCache>> = None;
    // graph-level per-node profiling sink, populated when --telemetry-json
    // is set and the backend executes through the graph IR
    let mut graph_tele: Option<Arc<Telemetry>> = None;
    let want_tele = telemetry_json.is_some();
    let started = match backend_name.as_str() {
        "pjrt" => start(&dir, cfg),
        "native" => {
            // load the plan cache once: the native backend resolves
            // per-layer tile configs from it AND the router resolves
            // Policy::Tuned against it (so clear cfg.plan_cache — the
            // server must not parse the same file a second time)
            let cache = plan_cache.as_ref().and_then(|p| match PlanCache::load(p) {
                Ok(c) => Some(Arc::new(c)),
                Err(e) => {
                    eprintln!("[serve] plan cache {}: {e} (serving untuned)", p.display());
                    None
                }
            });
            cfg.policy = cfg.policy.clone().resolve(cache.as_deref());
            cfg.plan_cache = None;
            native_cache = cache.clone();
            if precision != Precision::Fp32
                && !matches!(
                    flag(args, "--model").as_deref(),
                    Some("bert" | "vgg" | "vgg16" | "nmt" | "decoder")
                )
            {
                eprintln!(
                    "[serve] --precision applies to the graph-compiled zoo models; \
                     nano/bert-ffn serve f32"
                );
            }
            // --model picks what gets compiled: "bert"/"vgg"/"nmt" build
            // the zoo model through the layer-graph IR (per-layer packed
            // sparse weights, workspace-arena execution); "bert-ffn"
            // keeps the BERT-base FFN widths the autotuner tunes
            // (M = batch*seq = 256 matches the tuner's default m-cap);
            // "nano"/default the fast residual-MLP surrogate
            let backend: tilewise::error::Result<Arc<dyn Backend>> =
                match flag(args, "--model").as_deref() {
                    Some(m @ ("bert" | "vgg" | "vgg16" | "nmt" | "decoder")) => ZooSpec::for_model(m)
                        .and_then(|mut s| {
                            s.precision = precision;
                            s.fuse = !no_fusion;
                            ZooBackend::new(s, cache)
                        })
                        .map(|mut b| {
                            if want_tele {
                                graph_tele = Some(b.enable_telemetry());
                            }
                            Arc::new(b) as Arc<dyn Backend>
                        }),
                    Some("bert-ffn") => {
                        let spec =
                            NativeModelSpec { fuse: !no_fusion, ..NativeModelSpec::bert_base(8, 32) };
                        NativeBackend::new(spec, cache).map(|mut b| {
                            if want_tele {
                                graph_tele = Some(b.enable_telemetry());
                            }
                            Arc::new(b) as Arc<dyn Backend>
                        })
                    }
                    None | Some("nano") => NativeBackend::new(
                        NativeModelSpec { fuse: !no_fusion, ..NativeModelSpec::default() },
                        cache,
                    )
                    .map(|mut b| {
                        if want_tele {
                            graph_tele = Some(b.enable_telemetry());
                        }
                        Arc::new(b) as Arc<dyn Backend>
                    }),
                    Some(other) => {
                        eprintln!("[serve] unknown native model {other:?}; serving nano default");
                        let spec =
                            NativeModelSpec { fuse: !no_fusion, ..NativeModelSpec::default() };
                        NativeBackend::new(spec, cache).map(|mut b| {
                            if want_tele {
                                graph_tele = Some(b.enable_telemetry());
                            }
                            Arc::new(b) as Arc<dyn Backend>
                        })
                    }
                };
            backend.and_then(|b| start_with_backend(b, cfg))
        }
        other => {
            eprintln!("unknown backend {other:?} (expected pjrt|native)");
            return 2;
        }
    };
    let handle = match started {
        Ok(h) => h,
        Err(e) => {
            eprintln!("failed to start server: {e:#}");
            return 1;
        }
    };
    // --telemetry-json: periodic background dumps while the client runs,
    // plus one final dump after the last response
    let stop = Arc::new(AtomicBool::new(false));
    let dumper = telemetry_json.clone().map(|path| {
        let metrics = handle.metrics.clone();
        let tele = graph_tele.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_millis(500));
                write_telemetry(&path, &metrics, tele.as_deref());
            }
        })
    });
    println!(
        "serving[{backend_name}]: workers={} intra-threads={intra_threads} batch={} seq={} d_model={} classes={} mode={}{} precision={} simd={}",
        handle.workers,
        handle.batch,
        handle.seq,
        handle.d_model,
        handle.n_classes,
        if dynamic_batch { "dynamic-m" } else { "padded" },
        if low_latency { "+low-latency+fast-lane" } else { "" },
        precision.label(),
        tilewise::gemm::micro::active_label()
    );
    let len = handle.seq * handle.d_model;
    let mut rng = Rng::new(123);
    if decode_sessions > 0 {
        // streaming decode client: open-loop session arrivals with mixed
        // prompt/generation lengths through the continuous-batching lane
        let Some(caps) = handle.decode_caps else {
            eprintln!(
                "--decode needs a streaming-capable model \
                 (--backend native --model nmt|decoder)"
            );
            return 2;
        };
        let mut streams = Vec::with_capacity(decode_sessions);
        for i in 0..decode_sessions {
            let prompt_rows = 1 + i % caps.max_steps.saturating_sub(2).max(1);
            let new_tokens = (caps.max_steps - prompt_rows).min(4).max(1);
            let prompt: Vec<f32> =
                (0..prompt_rows * caps.d_in).map(|_| rng.normal_f32()).collect();
            streams.push(handle.submit_decode(prompt, None, new_tokens));
            std::thread::sleep(std::time::Duration::from_secs_f64(rng.exponential(rate)));
        }
        let mut tokens = 0usize;
        let mut dfailed = 0usize;
        for stream in streams {
            match stream.wait() {
                Ok(resp) => tokens += resp.tokens,
                Err(_) => dfailed += 1,
            }
        }
        let d = handle.metrics.decode_stats();
        println!(
            "decode: {decode_sessions} sessions -> {tokens} tokens ({dfailed} failed), \
             {:.1} tok/s, mean active slots {:.2}, step p50 {:.3}ms p95 {:.3}ms",
            d.tokens_per_sec, d.mean_active_slots, d.step_p50_ms, d.step_p95_ms
        );
    }
    let mut pending = Vec::new();
    for _ in 0..requests {
        let x: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
        // under --low-latency the client exercises the M=1 fast lane
        // (submit_fast degrades to the batched path without it)
        let stream =
            if low_latency { handle.submit_fast(x, None) } else { handle.submit(x, None) };
        pending.push(stream);
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exponential(rate)));
    }
    let mut ok = 0;
    let mut failed = 0;
    for stream in pending {
        match stream.wait() {
            Ok(_) => ok += 1,
            Err(_) => failed += 1,
        }
    }
    let snap = handle.metrics.full_snapshot();
    println!(
        "completed {ok}/{requests} requests ({failed} errored, {} shed, {} execute failures), throughput {:.1} req/s",
        snap.sheds, snap.errors, snap.throughput_rps
    );
    if handle.workers > 1 {
        let split: Vec<String> = snap.per_worker.iter().map(|c| c.to_string()).collect();
        println!("  per-worker completions: [{}]", split.join(", "));
    }
    if let Some(cache) = handle.plan_cache.as_ref().or(native_cache.as_ref()) {
        println!("  plan cache: {} tuned entries loaded", cache.len());
    }
    println!(
        "  batches executed: {} ({} padded rows avoided by dynamic-M)",
        snap.batches, snap.padded_rows_avoided
    );
    for s in &snap.variants {
        println!(
            "  {:<12} n={:<5} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms batch={:.1} occ={:.0}%",
            s.variant,
            s.count,
            s.mean_ms,
            s.p50_ms,
            s.p95_ms,
            s.p99_ms,
            s.mean_batch,
            s.mean_occupancy * 100.0
        );
    }
    // request-stage breakdown: where the end-to-end latency actually went
    for vs in &snap.stages {
        let cols: Vec<String> =
            vs.stages.iter().map(|st| format!("{} {:.2}ms", st.stage, st.mean_ms)).collect();
        println!("  stages[{}]: {}", vs.variant, cols.join(" | "));
    }
    if !snap.exemplars.is_empty() {
        println!("  slow exemplars retained: {}", snap.exemplars.len());
    }
    if let Some(lanes) = handle.intra_lane_stats() {
        let busy: Vec<String> = lanes.iter().map(|l| format!("{:.2}s", l.busy_secs)).collect();
        println!("  intra-pool lane busy: [{}]", busy.join(", "));
    }
    stop.store(true, Ordering::Relaxed);
    if let Some(j) = dumper {
        let _ = j.join();
    }
    if let Some(path) = &telemetry_json {
        write_telemetry(path, &handle.metrics, graph_tele.as_deref());
        println!("  telemetry dumped to {}", path.display());
    }
    0
}

/// One `--telemetry-json` dump: the full metrics snapshot (latency
/// percentiles, stage spans, slow exemplars) plus the per-node graph
/// profile when the backend carries one.
fn write_telemetry(
    path: &std::path::Path,
    metrics: &tilewise::coordinator::Metrics,
    tele: Option<&Telemetry>,
) {
    use tilewise::json::obj;
    let mut fields = vec![("snapshot", metrics.full_snapshot().to_json())];
    if let Some(t) = tele {
        fields.push(("graph", t.report()));
    }
    if let Err(e) = std::fs::write(path, obj(fields).to_string()) {
        eprintln!("[serve] telemetry dump {}: {e}", path.display());
    }
}

/// `profile`: run every zoo model x variant under the graph profiler and
/// emit Fig. 10-style per-node attribution (wall time, dispatched tile
/// config, intra-op threads, GFLOP/s) plus an op-kind breakdown.
fn cmd_profile(args: &[String]) -> i32 {
    use tilewise::exec::PreparedModel as _;
    use tilewise::json::{arr, num, obj, s, Json};
    let models: Vec<String> = match flag(args, "--model") {
        Some(m) => vec![m],
        None => vec!["bert".into(), "vgg".into(), "nmt".into()],
    };
    let out = PathBuf::from(flag(args, "--out").unwrap_or_else(|| "BENCH_profile.json".into()));
    let runs: usize = flag(args, "--runs").and_then(|v| v.parse().ok()).unwrap_or(3).max(1);
    let intra: usize = flag(args, "--intra-threads").and_then(|v| v.parse().ok()).unwrap_or(1);
    let variants = ["model_dense", "model_tw", "model_tvw", "model_vw24"];
    let mut model_jsons: Vec<Json> = Vec::new();
    for model in &models {
        let spec = match ZooSpec::for_model(model) {
            Ok(sp) => sp.with_variants(&variants),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let mut backend = match ZooBackend::new(spec, None) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("compiling {model}: {e}");
                return 1;
            }
        };
        let tele = backend.enable_telemetry();
        let pool = (intra > 1).then(|| Arc::new(tilewise::pool::ThreadPool::new(intra)));
        let mut m = match backend.load_with_intra(pool) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("loading {model}: {e}");
                return 1;
            }
        };
        let dims = m.dims();
        let x: Vec<f32> = (0..dims.batch * dims.per_request_len())
            .map(|i| ((i % 13) as f32 - 6.0) * 0.05)
            .collect();
        // one warmup sweep (packs nothing, just warms caches), then the
        // measured runs the attribution is taken from
        for v in variants {
            if let Err(e) = m.run(v, &x) {
                eprintln!("{model}/{v}: {e}");
                return 1;
            }
        }
        tele.reset();
        let t0 = Instant::now();
        for _ in 0..runs {
            for v in variants {
                if let Err(e) = m.run(v, &x) {
                    eprintln!("{model}/{v}: {e}");
                    return 1;
                }
            }
        }
        let e2e = t0.elapsed().as_secs_f64();
        println!("{model}: {runs} run(s) x {} variants in {:.1}ms", variants.len(), e2e * 1e3);
        let mut variant_jsons: Vec<Json> = Vec::new();
        for vp in tele.variants() {
            let fwd = vp.forward_secs();
            let coverage = if fwd > 0.0 { vp.attributed_secs() / fwd } else { 0.0 };
            println!(
                "  {:<12} forward {:>8.2}ms/run  attributed {:>5.1}%",
                vp.variant,
                fwd * 1e3 / vp.forwards().max(1) as f64,
                coverage * 100.0
            );
            let mut nodes: Vec<_> = vp.nodes.iter().filter(|n| n.calls() > 0).collect();
            nodes.sort_by(|a, b| b.secs().total_cmp(&a.secs()));
            for n in nodes.iter().take(3) {
                let (last_m, bm, bk, threads) = n.last_dispatch();
                println!(
                    "    {:<16} {:>8.2}ms  {:>7.2} GFLOP/s  m={last_m} bm={bm} bk={bk} t={threads} kernel={} epilogue={} avoided={}KB",
                    n.name,
                    n.secs() * 1e3,
                    n.gflops(),
                    n.last_micro(),
                    n.last_epilogue(),
                    n.bytes_avoided() / 1024
                );
            }
            variant_jsons.push(obj(vec![("coverage", num(coverage)), ("profile", vp.to_json())]));
        }
        model_jsons.push(obj(vec![("model", s(model)), ("variants", arr(variant_jsons))]));
    }
    let json = obj(vec![
        ("bench", s("profile")),
        ("runs", num(runs as f64)),
        ("intra_threads", num(intra as f64)),
        ("models", arr(model_jsons)),
    ]);
    match std::fs::write(&out, json.to_string()) {
        Ok(()) => {
            println!("wrote per-node profiles to {}", out.display());
            0
        }
        Err(e) => {
            eprintln!("writing {}: {e}", out.display());
            1
        }
    }
}

fn cmd_figure(args: &[String]) -> i32 {
    let which = args.first().map(String::as_str).unwrap_or("all");
    let csv_dir = flag(args, "--csv").map(PathBuf::from);
    let mut tables = Vec::new();
    match which {
        "fig6a" => tables.push(fig6::fig6a()),
        "fig6b" => tables.push(fig6::fig6b()),
        "fig6c" => tables.push(fig6::fig6c()),
        "fig7a" => tables.push(fig7::fig7a()),
        "fig7b" => tables.push(fig7::fig7b()),
        "fig8" => tables.extend(fig8::fig8_all()),
        "fig9" => {
            println!("{}", fig9::fig9_heatmaps());
            tables.push(fig9::fig9_stats());
        }
        "fig10" => tables.extend(fig10::fig10_all()),
        "fig11" => tables.extend(fig10::fig11_all()),
        "headline" => tables.push(headline::headline()),
        "all" => {
            tables.push(fig6::fig6a());
            tables.push(fig6::fig6b());
            tables.push(fig6::fig6c());
            tables.push(fig7::fig7a());
            tables.push(fig7::fig7b());
            tables.extend(fig8::fig8_all());
            tables.push(fig9::fig9_stats());
            tables.extend(fig10::fig10_all());
            tables.extend(fig10::fig11_all());
            tables.push(headline::headline());
        }
        other => {
            eprintln!("unknown figure {other:?}");
            return 2;
        }
    }
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.render());
        if let Some(dir) = &csv_dir {
            let _ = std::fs::create_dir_all(dir);
            let path = dir.join(format!("{}_{i}.csv", t.id));
            if let Err(e) = std::fs::write(&path, t.to_csv()) {
                eprintln!("writing {}: {e}", path.display());
            }
        }
    }
    0
}

fn cmd_inspect() -> i32 {
    println!("{}", fig9::fig9_heatmaps());
    println!("{}", fig9::fig9_stats().render());
    0
}

fn parse_pattern(name: &str, g: usize) -> Option<Pattern> {
    Some(match name {
        "ew" => Pattern::Ew,
        "vw" => Pattern::Vw { m: 4 },
        "vw16" => Pattern::Vw { m: 16 },
        "bw" => Pattern::Bw { g },
        "tw" => Pattern::Tw { g },
        "tew" => Pattern::Tew { g, delta_pct: 5 },
        "tvw" => Pattern::Tvw { g, m: 4 },
        _ => return None,
    })
}

fn cmd_prune(args: &[String]) -> i32 {
    let sparsity: f64 = flag(args, "--sparsity").and_then(|v| v.parse().ok()).unwrap_or(0.75);
    let g: usize = flag(args, "--g").and_then(|v| v.parse().ok()).unwrap_or(64);
    let pname = flag(args, "--pattern").unwrap_or_else(|| "tw".into());
    let Some(pattern) = parse_pattern(&pname, g) else {
        eprintln!("unknown pattern {pname:?}");
        return 2;
    };
    let mut rng = Rng::new(1);
    let w = Matrix::randn(512, 512, &mut rng);
    let pruner = tilewise::pruner::MultiStagePruner::new(pattern, sparsity, 0.25);
    let (_, mask, reports) = pruner.run(&w, |_, _| {});
    println!("pattern {} target {sparsity} on 512x512:", pattern.label());
    for r in reports {
        println!("  stage target={:.2} achieved={:.4}", r.target_sparsity, r.achieved_sparsity);
    }
    let stats = tilewise::sparse::mask_stats(&mask, 32);
    println!(
        "final sparsity={:.4} block_var={:.5} irregularity={:.4}",
        stats.sparsity, stats.block_variance, stats.irregularity
    );
    0
}

fn cmd_simulate(args: &[String]) -> i32 {
    let m: usize = flag(args, "--m").and_then(|v| v.parse().ok()).unwrap_or(4096);
    let k: usize = flag(args, "--k").and_then(|v| v.parse().ok()).unwrap_or(4096);
    let n: usize = flag(args, "--n").and_then(|v| v.parse().ok()).unwrap_or(4096);
    let sparsity: f64 = flag(args, "--sparsity").and_then(|v| v.parse().ok()).unwrap_or(0.75);
    let g: usize = flag(args, "--g").and_then(|v| v.parse().ok()).unwrap_or(128);
    let shape = GemmShape::new(m, k, n);
    let specs = gpusim::a100();
    let cal = Calibration::default();
    let dense_tc = gpusim::dense_plan(shape, Pipe::TensorFp16, &specs, &cal).latency(&specs);
    let dense_cuda = gpusim::dense_plan(shape, Pipe::CudaFp32, &specs, &cal).latency(&specs);
    let tiles = gpusim::tw_uniform_tiles(shape, sparsity, g);
    let tw =
        gpusim::tw_latency(shape, &tiles, g, Pipe::TensorFp16, TwStrategy::FusedCto, &specs, &cal);
    let tvw_tiles = gpusim::tw_uniform_tiles(shape, (1.0 - 2.0 * (1.0 - sparsity)).max(0.0), g);
    let tvw = gpusim::tvw_latency(shape, &tvw_tiles, g, &specs, &cal);
    let vw = gpusim::vw24_plan(shape, false, &specs, &cal).latency(&specs);
    let ew = gpusim::ew_plan(shape, sparsity, &specs, &cal).latency(&specs);
    println!("GEMM {m}x{k}x{n} @ sparsity {sparsity} (G={g}), simulated on {}:", specs.name);
    println!("  dense  TC    {:.3} ms   (1.00x)", dense_tc * 1e3);
    println!("  TW     TC    {:.3} ms   ({:.2}x)", tw * 1e3, dense_tc / tw);
    println!("  TVW    STC   {:.3} ms   ({:.2}x)", tvw * 1e3, dense_tc / tvw);
    println!("  VW-4   STC   {:.3} ms   ({:.2}x)", vw * 1e3, dense_tc / vw);
    println!("  dense  CUDA  {:.3} ms   (1.00x vs CUDA)", dense_cuda * 1e3);
    println!("  EW     CUDA  {:.3} ms   ({:.2}x vs CUDA)", ew * 1e3, dense_cuda / ew);
    0
}

fn cmd_simulate_model(args: &[String]) -> i32 {
    use tilewise::gpusim::{dense_plan, report, tw_latency, tw_uniform_tiles};
    use tilewise::models;
    let name = flag(args, "--model").unwrap_or_else(|| "bert".into());
    let sparsity: f64 = flag(args, "--sparsity").and_then(|v| v.parse().ok()).unwrap_or(0.75);
    let g: usize = flag(args, "--g").and_then(|v| v.parse().ok()).unwrap_or(128);
    let workload = match name.as_str() {
        "vgg16" => models::vgg16(),
        "resnet18" => models::resnet18(),
        "resnet50" => models::resnet50(),
        "nmt" => models::nmt(128),
        _ => models::bert_base(8, 128),
    };
    let specs = gpusim::a100();
    let cal = Calibration::default();
    println!(
        "{} per-layer breakdown @ TW-{g} {:.0}% sparsity (simulated {}):",
        workload.name, sparsity * 100.0, specs.name
    );
    println!(
        "{:<16}{:>22}{:>12}{:>12}{:>10}{:>12}{:>10}",
        "layer", "shape(MxKxN)xcount", "dense(us)", "tw(us)", "speedup", "bound", "occup"
    );
    let mut dense_total = 0.0;
    let mut tw_total = 0.0;
    for layer in &workload.layers {
        let d_kernel = dense_plan(layer.shape, Pipe::TensorFp16, &specs, &cal);
        let d = d_kernel.latency(&specs);
        let r = report(&d_kernel, &specs);
        let t = if layer.prunable {
            let tiles = tw_uniform_tiles(layer.shape, sparsity, g);
            tw_latency(layer.shape, &tiles, g, Pipe::TensorFp16, TwStrategy::FusedCto, &specs, &cal)
        } else {
            d
        };
        dense_total += d * layer.count as f64;
        tw_total += t * layer.count as f64;
        println!(
            "{:<16}{:>22}{:>12.1}{:>12.1}{:>9.2}x{:>12}{:>9.2}",
            layer.name,
            format!("{}x{}x{} x{}", layer.shape.m, layer.shape.k, layer.shape.n, layer.count),
            d * 1e6,
            t * 1e6,
            d / t,
            r.bound.label(),
            r.occupancy
        );
    }
    println!(
        "total: dense {:.1}us -> TW {:.1}us = {:.2}x model speedup",
        dense_total * 1e6,
        tw_total * 1e6,
        dense_total / tw_total
    );
    0
}
