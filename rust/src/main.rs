//! `tilewise` CLI — leader entrypoint for the serving stack, the figure
//! harnesses, and the inspection tools.
//!
//! Subcommands (hand-rolled parser; the offline registry has no clap):
//!   serve             run the serving stack with a synthetic open-loop client
//!   autotune          tune a model zoo entry's GEMMs, write the plan cache
//!   figure <id|all>   regenerate a paper figure (fig6a..fig11, headline)
//!   inspect-patterns  print the Fig. 9 mask heatmaps + statistics
//!   prune             run the multi-stage pruner on a synthetic matrix
//!   simulate          one-off gpusim query (shape x pattern x sparsity)

use std::path::PathBuf;
use std::sync::Arc;

use tilewise::autotune::{MeasureOpts, PatternFamily, PlanCache, Tuner, TunerOpts};
use tilewise::coordinator::{start, start_with_backend, BatcherConfig, Policy, ServerConfig};
use tilewise::exec::{Backend, NativeBackend, NativeModelSpec, ZooBackend, ZooSpec};
use tilewise::figures::{fig10, fig6, fig7, fig8, fig9, headline};
use tilewise::gpusim::{self, Calibration, GemmShape, Pipe, TwStrategy};
use tilewise::models::{self, ModelWorkload};
use tilewise::sparse::Pattern;
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("serve") => cmd_serve(&args[1..]),
        Some("autotune") => cmd_autotune(&args[1..]),
        Some("figure") => cmd_figure(&args[1..]),
        Some("inspect-patterns") => cmd_inspect(),
        Some("prune") => cmd_prune(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("simulate-model") => cmd_simulate_model(&args[1..]),
        _ => {
            eprintln!(
                "usage: tilewise <command>\n\
                 \n\
                 commands:\n\
                 \x20 serve [--backend pjrt|native] [--workers N] [--intra-threads N] [--artifacts DIR]\n\
                 \x20       [--requests N] [--rate RPS] [--policy dense|tw|tvw|rr|adaptive|tuned]\n\
                 \x20       [--plan-cache FILE] [--model bert|vgg|nmt|nano|bert-ffn]\n\
                 \x20       [--low-latency] [--padded]\n\
                 \x20       (bert/vgg/nmt serve the graph-compiled zoo model; nano the\n\
                 \x20        residual-MLP surrogate; bert-ffn the BERT-base FFN widths;\n\
                 \x20        --low-latency dispatches partial batches without waiting;\n\
                 \x20        --padded disables dynamic effective-batch execution)\n\
                 \x20 autotune [--model vgg16|resnet18|resnet50|nmt|bert] [--sparsity S] [--out FILE]\n\
                 \x20          [--threads T] [--m-cap M] [--budget-ms MS] [--quick]\n\
                 \x20 figure <fig6a|fig6b|fig6c|fig7a|fig7b|fig8|fig9|fig10|fig11|headline|all> [--csv DIR]\n\
                 \x20 inspect-patterns\n\
                 \x20 prune [--pattern ew|vw|bw|tw|tew|tvw] [--sparsity S] [--g G]\n\
                 \x20 simulate [--m M --k K --n N] [--sparsity S] [--g G]\n\
                 \x20 simulate-model [--model vgg16|resnet18|resnet50|nmt|bert] [--sparsity S] [--g G]"
            );
            2
        }
    };
    std::process::exit(code);
}

fn workload_by_name(name: &str) -> Option<ModelWorkload> {
    Some(match name {
        "vgg16" => models::vgg16(),
        "resnet18" => models::resnet18(),
        "resnet50" => models::resnet50(),
        "nmt" => models::nmt(128),
        "bert" => models::bert_base(8, 128),
        _ => return None,
    })
}

fn cmd_autotune(args: &[String]) -> i32 {
    let model = flag(args, "--model").unwrap_or_else(|| "bert".into());
    let sparsity: f64 = flag(args, "--sparsity").and_then(|v| v.parse().ok()).unwrap_or(0.75);
    let out = PathBuf::from(flag(args, "--out").unwrap_or_else(|| "plans.json".into()));
    let threads: usize = flag(args, "--threads").and_then(|v| v.parse().ok()).unwrap_or_else(|| {
        std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1)
    });
    let m_cap: usize = flag(args, "--m-cap").and_then(|v| v.parse().ok()).unwrap_or(256);
    let quick = args.iter().any(|a| a == "--quick");

    let Some(workload) = workload_by_name(&model) else {
        eprintln!("unknown model {model:?} (expected vgg16|resnet18|resnet50|nmt|bert)");
        return 2;
    };
    let mut opts = TunerOpts {
        sparsity,
        nthreads: threads,
        m_cap: Some(m_cap),
        ..TunerOpts::default()
    };
    opts.measure = if quick { MeasureOpts::quick() } else { MeasureOpts::default() };
    if let Some(ms) = flag(args, "--budget-ms").and_then(|v| v.parse::<f64>().ok()) {
        opts.measure.budget_secs = ms / 1e3;
    }
    let tuner = Tuner::new(opts);

    println!(
        "autotuning {} ({} prunable layers) @ {:.0}% sparsity, {threads} thread(s), m-cap {m_cap}",
        workload.name,
        workload.prunable_layers().count(),
        sparsity * 100.0
    );
    let families = [PatternFamily::Dense, PatternFamily::Tw, PatternFamily::Tvw];
    let (cache, results) = tuner.tune_workload(&workload, &model, &families);

    println!(
        "{:<22}{:>8}{:>14}{:>12}{:>12}{:>9}   {}",
        "shape(MxKxN)", "family", "default(us)", "tuned(us)", "model(us)", "speedup", "winner"
    );
    for r in &results {
        let e = &r.entry;
        println!(
            "{:<22}{:>8}{:>14.1}{:>12.1}{:>12.1}{:>8.2}x   {}",
            format!("{}x{}x{}", e.key.m, e.key.k, e.key.n),
            e.key.pattern,
            e.default_us,
            e.measured_us,
            e.model_us,
            e.speedup(),
            e.candidate().map(|c| c.label()).unwrap_or_default(),
        );
    }
    if let Some(variant) = cache.model_variant(&model) {
        println!("serving recommendation for {model:?}: {variant}");
    }
    match cache.save(&out) {
        Ok(()) => {
            println!("wrote {} tuned entries to {}", cache.len(), out.display());
            0
        }
        Err(e) => {
            eprintln!("failed to write plan cache: {e}");
            1
        }
    }
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn cmd_serve(args: &[String]) -> i32 {
    let dir = PathBuf::from(flag(args, "--artifacts").unwrap_or_else(|| "artifacts".into()));
    let backend_name = flag(args, "--backend").unwrap_or_else(|| "pjrt".into());
    let workers: usize = flag(args, "--workers").and_then(|v| v.parse().ok()).unwrap_or(1);
    // intra-op kernel lanes of the shared pool (DESIGN.md §5): default
    // serial; size workers + intra_threads - 1 near the core count
    let intra_threads: usize =
        flag(args, "--intra-threads").and_then(|v| v.parse().ok()).unwrap_or(1);
    let requests: usize = flag(args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(64);
    let rate: f64 = flag(args, "--rate").and_then(|v| v.parse().ok()).unwrap_or(50.0);
    let plan_cache = flag(args, "--plan-cache").map(PathBuf::from);
    let policy = match flag(args, "--policy").as_deref() {
        Some("dense") => Policy::Fixed("model_dense".into()),
        Some("tvw") => Policy::Fixed("model_tvw".into()),
        Some("rr") => Policy::RoundRobin(vec![
            "model_dense".into(),
            "model_tw".into(),
            "model_tvw".into(),
        ]),
        Some("adaptive") => Policy::Adaptive {
            dense: "model_dense".into(),
            sparse: "model_tvw".into(),
            queue_threshold: 8,
        },
        Some("tuned") => Policy::Tuned {
            // the cache keys recommendations under the autotune CLI's
            // model names; `serve --model vgg` maps to the tuned "vgg16"
            model: match flag(args, "--model").as_deref() {
                Some("vgg") => "vgg16".into(),
                Some(m) => m.into(),
                None => "bert".into(),
            },
            fallback: "model_dense".into(),
        },
        // no explicit policy: the native backend round-robins so one run
        // exercises dense/TW/TVW end-to-end; pjrt keeps the TW default
        None if backend_name == "native" => Policy::RoundRobin(vec![
            "model_dense".into(),
            "model_tw".into(),
            "model_tvw".into(),
        ]),
        _ => Policy::Fixed("model_tw".into()),
    };
    // --low-latency: dispatch partial batches as soon as the queue is
    // drained; --padded: keep the historical full-B zero-padded execution
    // (dynamic effective-batch is the default)
    let low_latency = args.iter().any(|a| a == "--low-latency");
    let dynamic_batch = !args.iter().any(|a| a == "--padded");
    let batcher = if low_latency {
        BatcherConfig::low_latency(BatcherConfig::default().max_batch)
    } else {
        BatcherConfig::default()
    };
    let mut cfg = ServerConfig {
        batcher,
        policy,
        variants: ServerConfig::default().variants,
        max_queue: 0,
        plan_cache: plan_cache.clone(),
        workers,
        intra_threads,
        dynamic_batch,
    };
    let mut native_cache: Option<Arc<PlanCache>> = None;
    let started = match backend_name.as_str() {
        "pjrt" => start(&dir, cfg),
        "native" => {
            // load the plan cache once: the native backend resolves
            // per-layer tile configs from it AND the router resolves
            // Policy::Tuned against it (so clear cfg.plan_cache — the
            // server must not parse the same file a second time)
            let cache = plan_cache.as_ref().and_then(|p| match PlanCache::load(p) {
                Ok(c) => Some(Arc::new(c)),
                Err(e) => {
                    eprintln!("[serve] plan cache {}: {e} (serving untuned)", p.display());
                    None
                }
            });
            cfg.policy = cfg.policy.clone().resolve(cache.as_deref());
            cfg.plan_cache = None;
            native_cache = cache.clone();
            // --model picks what gets compiled: "bert"/"vgg"/"nmt" build
            // the zoo model through the layer-graph IR (per-layer packed
            // sparse weights, workspace-arena execution); "bert-ffn"
            // keeps the BERT-base FFN widths the autotuner tunes
            // (M = batch*seq = 256 matches the tuner's default m-cap);
            // "nano"/default the fast residual-MLP surrogate
            let backend: tilewise::error::Result<Arc<dyn Backend>> =
                match flag(args, "--model").as_deref() {
                    Some(m @ ("bert" | "vgg" | "vgg16" | "nmt")) => ZooSpec::for_model(m)
                        .and_then(|s| ZooBackend::new(s, cache))
                        .map(|b| Arc::new(b) as Arc<dyn Backend>),
                    Some("bert-ffn") => {
                        NativeBackend::new(NativeModelSpec::bert_base(8, 32), cache)
                            .map(|b| Arc::new(b) as Arc<dyn Backend>)
                    }
                    None | Some("nano") => NativeBackend::new(NativeModelSpec::default(), cache)
                        .map(|b| Arc::new(b) as Arc<dyn Backend>),
                    Some(other) => {
                        eprintln!("[serve] unknown native model {other:?}; serving nano default");
                        NativeBackend::new(NativeModelSpec::default(), cache)
                            .map(|b| Arc::new(b) as Arc<dyn Backend>)
                    }
                };
            backend.and_then(|b| start_with_backend(b, cfg))
        }
        other => {
            eprintln!("unknown backend {other:?} (expected pjrt|native)");
            return 2;
        }
    };
    let handle = match started {
        Ok(h) => h,
        Err(e) => {
            eprintln!("failed to start server: {e:#}");
            return 1;
        }
    };
    println!(
        "serving[{backend_name}]: workers={} intra-threads={intra_threads} batch={} seq={} d_model={} classes={} mode={}{}",
        handle.workers,
        handle.batch,
        handle.seq,
        handle.d_model,
        handle.n_classes,
        if dynamic_batch { "dynamic-m" } else { "padded" },
        if low_latency { "+low-latency" } else { "" }
    );
    let len = handle.seq * handle.d_model;
    let mut rng = Rng::new(123);
    let mut pending = Vec::new();
    for _ in 0..requests {
        let x: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
        pending.push(handle.submit(x, None));
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exponential(rate)));
    }
    let mut ok = 0;
    let mut failed = 0;
    for rx in pending {
        match rx.recv() {
            Ok(resp) if resp.is_ok() => ok += 1,
            Ok(_) => failed += 1,
            Err(_) => {}
        }
    }
    let snap = handle.metrics.full_snapshot();
    println!(
        "completed {ok}/{requests} requests ({failed} errored, {} shed, {} execute failures), throughput {:.1} req/s",
        snap.sheds, snap.errors, snap.throughput_rps
    );
    if handle.workers > 1 {
        let split: Vec<String> = snap.per_worker.iter().map(|c| c.to_string()).collect();
        println!("  per-worker completions: [{}]", split.join(", "));
    }
    if let Some(cache) = handle.plan_cache.as_ref().or(native_cache.as_ref()) {
        println!("  plan cache: {} tuned entries loaded", cache.len());
    }
    println!(
        "  batches executed: {} ({} padded rows avoided by dynamic-M)",
        snap.batches, snap.padded_rows_avoided
    );
    for s in &snap.variants {
        println!(
            "  {:<12} n={:<5} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms batch={:.1} occ={:.0}%",
            s.variant,
            s.count,
            s.mean_ms,
            s.p50_ms,
            s.p95_ms,
            s.p99_ms,
            s.mean_batch,
            s.mean_occupancy * 100.0
        );
    }
    0
}

fn cmd_figure(args: &[String]) -> i32 {
    let which = args.first().map(String::as_str).unwrap_or("all");
    let csv_dir = flag(args, "--csv").map(PathBuf::from);
    let mut tables = Vec::new();
    match which {
        "fig6a" => tables.push(fig6::fig6a()),
        "fig6b" => tables.push(fig6::fig6b()),
        "fig6c" => tables.push(fig6::fig6c()),
        "fig7a" => tables.push(fig7::fig7a()),
        "fig7b" => tables.push(fig7::fig7b()),
        "fig8" => tables.extend(fig8::fig8_all()),
        "fig9" => {
            println!("{}", fig9::fig9_heatmaps());
            tables.push(fig9::fig9_stats());
        }
        "fig10" => tables.extend(fig10::fig10_all()),
        "fig11" => tables.extend(fig10::fig11_all()),
        "headline" => tables.push(headline::headline()),
        "all" => {
            tables.push(fig6::fig6a());
            tables.push(fig6::fig6b());
            tables.push(fig6::fig6c());
            tables.push(fig7::fig7a());
            tables.push(fig7::fig7b());
            tables.extend(fig8::fig8_all());
            tables.push(fig9::fig9_stats());
            tables.extend(fig10::fig10_all());
            tables.extend(fig10::fig11_all());
            tables.push(headline::headline());
        }
        other => {
            eprintln!("unknown figure {other:?}");
            return 2;
        }
    }
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.render());
        if let Some(dir) = &csv_dir {
            let _ = std::fs::create_dir_all(dir);
            let path = dir.join(format!("{}_{i}.csv", t.id));
            if let Err(e) = std::fs::write(&path, t.to_csv()) {
                eprintln!("writing {}: {e}", path.display());
            }
        }
    }
    0
}

fn cmd_inspect() -> i32 {
    println!("{}", fig9::fig9_heatmaps());
    println!("{}", fig9::fig9_stats().render());
    0
}

fn parse_pattern(name: &str, g: usize) -> Option<Pattern> {
    Some(match name {
        "ew" => Pattern::Ew,
        "vw" => Pattern::Vw { m: 4 },
        "vw16" => Pattern::Vw { m: 16 },
        "bw" => Pattern::Bw { g },
        "tw" => Pattern::Tw { g },
        "tew" => Pattern::Tew { g, delta_pct: 5 },
        "tvw" => Pattern::Tvw { g, m: 4 },
        _ => return None,
    })
}

fn cmd_prune(args: &[String]) -> i32 {
    let sparsity: f64 = flag(args, "--sparsity").and_then(|v| v.parse().ok()).unwrap_or(0.75);
    let g: usize = flag(args, "--g").and_then(|v| v.parse().ok()).unwrap_or(64);
    let pname = flag(args, "--pattern").unwrap_or_else(|| "tw".into());
    let Some(pattern) = parse_pattern(&pname, g) else {
        eprintln!("unknown pattern {pname:?}");
        return 2;
    };
    let mut rng = Rng::new(1);
    let w = Matrix::randn(512, 512, &mut rng);
    let pruner = tilewise::pruner::MultiStagePruner::new(pattern, sparsity, 0.25);
    let (_, mask, reports) = pruner.run(&w, |_, _| {});
    println!("pattern {} target {sparsity} on 512x512:", pattern.label());
    for r in reports {
        println!("  stage target={:.2} achieved={:.4}", r.target_sparsity, r.achieved_sparsity);
    }
    let stats = tilewise::sparse::mask_stats(&mask, 32);
    println!(
        "final sparsity={:.4} block_var={:.5} irregularity={:.4}",
        stats.sparsity, stats.block_variance, stats.irregularity
    );
    0
}

fn cmd_simulate(args: &[String]) -> i32 {
    let m: usize = flag(args, "--m").and_then(|v| v.parse().ok()).unwrap_or(4096);
    let k: usize = flag(args, "--k").and_then(|v| v.parse().ok()).unwrap_or(4096);
    let n: usize = flag(args, "--n").and_then(|v| v.parse().ok()).unwrap_or(4096);
    let sparsity: f64 = flag(args, "--sparsity").and_then(|v| v.parse().ok()).unwrap_or(0.75);
    let g: usize = flag(args, "--g").and_then(|v| v.parse().ok()).unwrap_or(128);
    let shape = GemmShape::new(m, k, n);
    let specs = gpusim::a100();
    let cal = Calibration::default();
    let dense_tc = gpusim::dense_plan(shape, Pipe::TensorFp16, &specs, &cal).latency(&specs);
    let dense_cuda = gpusim::dense_plan(shape, Pipe::CudaFp32, &specs, &cal).latency(&specs);
    let tiles = gpusim::tw_uniform_tiles(shape, sparsity, g);
    let tw =
        gpusim::tw_latency(shape, &tiles, g, Pipe::TensorFp16, TwStrategy::FusedCto, &specs, &cal);
    let tvw_tiles = gpusim::tw_uniform_tiles(shape, (1.0 - 2.0 * (1.0 - sparsity)).max(0.0), g);
    let tvw = gpusim::tvw_latency(shape, &tvw_tiles, g, &specs, &cal);
    let vw = gpusim::vw24_plan(shape, false, &specs, &cal).latency(&specs);
    let ew = gpusim::ew_plan(shape, sparsity, &specs, &cal).latency(&specs);
    println!("GEMM {m}x{k}x{n} @ sparsity {sparsity} (G={g}), simulated on {}:", specs.name);
    println!("  dense  TC    {:.3} ms   (1.00x)", dense_tc * 1e3);
    println!("  TW     TC    {:.3} ms   ({:.2}x)", tw * 1e3, dense_tc / tw);
    println!("  TVW    STC   {:.3} ms   ({:.2}x)", tvw * 1e3, dense_tc / tvw);
    println!("  VW-4   STC   {:.3} ms   ({:.2}x)", vw * 1e3, dense_tc / vw);
    println!("  dense  CUDA  {:.3} ms   (1.00x vs CUDA)", dense_cuda * 1e3);
    println!("  EW     CUDA  {:.3} ms   ({:.2}x vs CUDA)", ew * 1e3, dense_cuda / ew);
    0
}

fn cmd_simulate_model(args: &[String]) -> i32 {
    use tilewise::gpusim::{dense_plan, report, tw_latency, tw_uniform_tiles};
    use tilewise::models;
    let name = flag(args, "--model").unwrap_or_else(|| "bert".into());
    let sparsity: f64 = flag(args, "--sparsity").and_then(|v| v.parse().ok()).unwrap_or(0.75);
    let g: usize = flag(args, "--g").and_then(|v| v.parse().ok()).unwrap_or(128);
    let workload = match name.as_str() {
        "vgg16" => models::vgg16(),
        "resnet18" => models::resnet18(),
        "resnet50" => models::resnet50(),
        "nmt" => models::nmt(128),
        _ => models::bert_base(8, 128),
    };
    let specs = gpusim::a100();
    let cal = Calibration::default();
    println!(
        "{} per-layer breakdown @ TW-{g} {:.0}% sparsity (simulated {}):",
        workload.name, sparsity * 100.0, specs.name
    );
    println!(
        "{:<16}{:>22}{:>12}{:>12}{:>10}{:>12}{:>10}",
        "layer", "shape(MxKxN)xcount", "dense(us)", "tw(us)", "speedup", "bound", "occup"
    );
    let mut dense_total = 0.0;
    let mut tw_total = 0.0;
    for layer in &workload.layers {
        let d_kernel = dense_plan(layer.shape, Pipe::TensorFp16, &specs, &cal);
        let d = d_kernel.latency(&specs);
        let r = report(&d_kernel, &specs);
        let t = if layer.prunable {
            let tiles = tw_uniform_tiles(layer.shape, sparsity, g);
            tw_latency(layer.shape, &tiles, g, Pipe::TensorFp16, TwStrategy::FusedCto, &specs, &cal)
        } else {
            d
        };
        dense_total += d * layer.count as f64;
        tw_total += t * layer.count as f64;
        println!(
            "{:<16}{:>22}{:>12.1}{:>12.1}{:>9.2}x{:>12}{:>9.2}",
            layer.name,
            format!("{}x{}x{} x{}", layer.shape.m, layer.shape.k, layer.shape.n, layer.count),
            d * 1e6,
            t * 1e6,
            d / t,
            r.bound.label(),
            r.occupancy
        );
    }
    println!(
        "total: dense {:.1}us -> TW {:.1}us = {:.2}x model speedup",
        dense_total * 1e6,
        tw_total * 1e6,
        dense_total / tw_total
    );
    0
}
