//! Native in-process backend: the serving path that runs the paper's
//! kernels for real, with zero external dependencies.
//!
//! At construction the backend builds a small residual-MLP classifier
//! (transformer-encoder shaped: per-block `d_model -> d_ff -> d_model`
//! GEMMs plus a dense head, the FFN pair that dominates BERT FLOPs), then
//! packs every prunable layer **once** into each serving variant's
//! kernel-ready form:
//!
//! - `model_dense` — raw row-major weights, run by `gemm::matmul_tiled_into`
//! - `model_tw`    — TW-pruned, `sparse::TwPlan` condensed tiles, run by
//!   the fused-CTO `gemm::tw_matmul_into_with`
//! - `model_tvw`   — TVW-pruned, `sparse::TvwPlan` (CTO + 2:4 metadata),
//!   run by `gemm::tvw_matmul_into_with`
//! - `model_vw24`  — plain 2:4 along K, `sparse::Vw24Plan`, run by
//!   `gemm::vw24_matmul_into_with`
//!
//! Per-GEMM [`TileConfig`]s are resolved from the autotune [`PlanCache`]
//! when one is supplied (the `(M, K, N, pattern, sparsity, threads=1)` key
//! the tuner writes), falling back to each family's historical default.
//! The packed plans live behind an `Arc`, so a pool of N workers shares
//! one copy of the weights; only the per-worker scratch matrices are
//! duplicated, and the request hot loop performs no allocation beyond the
//! response vector.

use std::sync::Arc;

use super::{Backend, ModelDims, PreparedModel};
use crate::autotune::{PatternFamily, PlanCache};
use crate::error::Result;
use crate::gemm::{
    matmul_parallel_into, matmul_tiled_into, tvw_matmul_into_with, tvw_matmul_parallel_into,
    tw_matmul_into_with, tw_matmul_parallel_into, vw24_matmul_into_with,
    vw24_matmul_parallel_into, TileConfig,
};
use crate::gpusim::GemmShape;
use crate::pool::ThreadPool;
use crate::sparse::{prune_tvw, prune_tw, prune_vw, TvwPlan, TwPlan, Vw24Plan};
use crate::tensor::Matrix;
use crate::util::Rng;
use crate::{anyhow, bail, ensure};

/// Shape + pruning recipe of the native model.  Weights are generated
/// deterministically from `seed`, so every backend constructed from the
/// same spec serves identical logits.
#[derive(Clone, Debug)]
pub struct NativeModelSpec {
    pub seq: usize,
    pub d_model: usize,
    /// FFN hidden width (the `d_model -> d_ff -> d_model` block pair).
    pub d_ff: usize,
    pub n_classes: usize,
    /// Residual FFN blocks stacked before the classifier head.
    pub n_layers: usize,
    /// Fixed serving batch (requests per invocation, padded).
    pub batch: usize,
    /// Target sparsity for the TW / TVW variants (TVW floors at 0.5).
    pub sparsity: f64,
    /// TW tile granularity G.
    pub g: usize,
    pub seed: u64,
    /// Which variants to pack (packing TW/TVW plans for large layers is
    /// the expensive part of construction; benches prune this list).
    pub variants: Vec<String>,
}

pub const NATIVE_VARIANTS: [&str; 4] = ["model_dense", "model_tw", "model_tvw", "model_vw24"];

impl Default for NativeModelSpec {
    /// A deliberately small "BERT-nano" so the native serving tests run in
    /// milliseconds: 2 blocks of 64 -> 128 -> 64 over 16-token requests.
    fn default() -> Self {
        NativeModelSpec {
            seq: 16,
            d_model: 64,
            d_ff: 128,
            n_classes: 8,
            n_layers: 2,
            batch: 8,
            sparsity: 0.75,
            g: 16,
            seed: 42,
            variants: NATIVE_VARIANTS.iter().map(|v| v.to_string()).collect(),
        }
    }
}

impl NativeModelSpec {
    /// BERT-base FFN geometry (the paper's dominant GEMMs), with the
    /// widths taken from the `models` zoo so the bench and the simulator
    /// agree on what "BERT-base shapes" means.  `seq` stays a parameter:
    /// serving latency is linear in tokens and benches trim it.
    pub fn bert_base(batch: usize, seq: usize) -> NativeModelSpec {
        let bert = crate::models::bert_base(batch, seq);
        let ffn1 = bert
            .layers
            .iter()
            .find(|l| l.name == "ffn1")
            .expect("bert_base workload has an ffn1 layer");
        NativeModelSpec {
            seq,
            d_model: ffn1.shape.k,
            d_ff: ffn1.shape.n,
            n_classes: 2,
            n_layers: 1,
            batch,
            sparsity: 0.75,
            g: 64,
            seed: 42,
            ..NativeModelSpec::default()
        }
    }

    /// Restrict which variants get packed.
    pub fn with_variants(mut self, variants: &[&str]) -> NativeModelSpec {
        self.variants = variants.iter().map(|v| v.to_string()).collect();
        self
    }
}

/// One packed GEMM operand plus its resolved cache-blocking.
struct PackedGemm {
    pack: Pack,
    cfg: TileConfig,
}

enum Pack {
    Dense(Matrix),
    Tw(TwPlan),
    Tvw(TvwPlan),
    Vw24(Vw24Plan),
}

/// One residual block: `up` (d_model -> d_ff), `down` (d_ff -> d_model).
struct Block {
    up: PackedGemm,
    down: PackedGemm,
}

/// One serving variant's fully packed network.
struct VariantNet {
    name: String,
    blocks: Vec<Block>,
    /// Classifier head (d_model -> n_classes), dense in every variant —
    /// the paper's "keep the small accuracy-critical layers dense" rule.
    head: PackedGemm,
}

/// The shared, immutable packed model (weights + plans + tile configs).
pub struct NativeBackend {
    dims: ModelDims,
    nets: Arc<Vec<VariantNet>>,
}

fn tile_for(
    cache: Option<&PlanCache>,
    shape: GemmShape,
    family: PatternFamily,
    sparsity: f64,
    fallback: TileConfig,
) -> TileConfig {
    // serving-time lookup: exact on (K, N, pattern), nearest on the rest —
    // the tuner keys DENSE at sparsity 0, caps M, and records its own
    // thread budget, so an exact-key probe would almost never hit
    cache
        .and_then(|c| c.lookup_tile_config(shape, family.label(), sparsity))
        .unwrap_or(fallback)
}

impl NativeBackend {
    /// Build and pack the model.  `plan_cache` is the autotuner's output
    /// (`tilewise autotune --out plans.json`); absent, every kernel runs
    /// at its historical default tile config.
    pub fn new(spec: NativeModelSpec, plan_cache: Option<Arc<PlanCache>>) -> Result<NativeBackend> {
        ensure!(
            spec.seq > 0 && spec.d_model > 0 && spec.d_ff > 0 && spec.n_classes > 0,
            "native model spec has a zero dimension: {spec:?}"
        );
        ensure!(spec.n_layers > 0 && spec.batch > 0, "native model needs n_layers/batch >= 1");
        ensure!(!spec.variants.is_empty(), "native model spec packs no variants");
        let wants_24 = spec
            .variants
            .iter()
            .any(|v| v == "model_tvw" || v == "model_vw24");
        ensure!(
            !wants_24 || (spec.d_model % 4 == 0 && spec.d_ff % 4 == 0),
            "2:4 variants need d_model and d_ff to be multiples of 4 (got {} / {})",
            spec.d_model,
            spec.d_ff
        );

        // Base weights, shared by every variant before pruning.
        let mut rng = Rng::new(spec.seed);
        let base: Vec<(Matrix, Matrix)> = (0..spec.n_layers)
            .map(|_| {
                (
                    Matrix::randn(spec.d_model, spec.d_ff, &mut rng),
                    Matrix::randn(spec.d_ff, spec.d_model, &mut rng),
                )
            })
            .collect();
        let head_w = Matrix::randn(spec.d_model, spec.n_classes, &mut rng);

        let tokens = spec.batch * spec.seq;
        let up_shape = GemmShape::new(tokens, spec.d_model, spec.d_ff);
        let down_shape = GemmShape::new(tokens, spec.d_ff, spec.d_model);
        let head_shape = GemmShape::new(spec.batch, spec.d_model, spec.n_classes);
        let cache = plan_cache.as_deref();

        let mut nets = Vec::with_capacity(spec.variants.len());
        for name in &spec.variants {
            let pack = |w: &Matrix, shape: GemmShape| -> Result<PackedGemm> {
                Ok(match name.as_str() {
                    "model_dense" => PackedGemm {
                        pack: Pack::Dense(w.clone()),
                        cfg: tile_for(
                            cache,
                            shape,
                            PatternFamily::Dense,
                            spec.sparsity,
                            TileConfig::dense_default(),
                        ),
                    },
                    "model_tw" => {
                        let tw = prune_tw(w, spec.sparsity, spec.g, None);
                        PackedGemm {
                            pack: Pack::Tw(TwPlan::encode(w, &tw)),
                            cfg: tile_for(
                                cache,
                                shape,
                                PatternFamily::Tw,
                                spec.sparsity,
                                TileConfig::tw_default(),
                            ),
                        }
                    }
                    "model_tvw" => {
                        let s = spec.sparsity.max(0.5);
                        let (tw, mask) = prune_tvw(w, s, spec.g);
                        PackedGemm {
                            pack: Pack::Tvw(TvwPlan::encode(w, &tw, &mask)),
                            cfg: tile_for(
                                cache,
                                shape,
                                PatternFamily::Tvw,
                                s,
                                TileConfig::tvw_default(),
                            ),
                        }
                    }
                    "model_vw24" => {
                        let mask = prune_vw(w, 0.5, 4);
                        let plan = Vw24Plan::encode(w, &mask)
                            .map_err(|e| anyhow!("packing 2:4 plan: {e}"))?;
                        PackedGemm {
                            pack: Pack::Vw24(plan),
                            cfg: tile_for(
                                cache,
                                shape,
                                PatternFamily::Vw24,
                                0.5,
                                TileConfig::vw_default(),
                            ),
                        }
                    }
                    other => {
                        bail!("unknown native variant {other:?} (expected {NATIVE_VARIANTS:?})")
                    }
                })
            };
            let mut blocks = Vec::with_capacity(spec.n_layers);
            for (w1, w2) in &base {
                blocks.push(Block { up: pack(w1, up_shape)?, down: pack(w2, down_shape)? });
            }
            // the head stays dense regardless of variant
            let head = PackedGemm {
                pack: Pack::Dense(head_w.clone()),
                cfg: tile_for(
                    cache,
                    head_shape,
                    PatternFamily::Dense,
                    spec.sparsity,
                    TileConfig::dense_default(),
                ),
            };
            nets.push(VariantNet { name: name.clone(), blocks, head });
        }

        Ok(NativeBackend {
            dims: ModelDims {
                batch: spec.batch,
                seq: spec.seq,
                d_model: spec.d_model,
                n_classes: spec.n_classes,
            },
            nets: Arc::new(nets),
        })
    }

    pub fn dims(&self) -> ModelDims {
        self.dims
    }
}

impl NativeBackend {
    /// Build one per-worker model instance; `intra` is the shared intra-op
    /// kernel pool (None = serial kernels at their tuned/default configs).
    fn load_native(&self, intra: Option<Arc<ThreadPool>>) -> NativeModel {
        let tokens = self.dims.batch * self.dims.seq;
        let (d_model, d_ff) = {
            // every net shares the base geometry; read it off the scratch
            // requirements of the first block (head-only nets have d_ff 0)
            let d_ff = self.nets.first().and_then(|n| n.blocks.first()).map_or(0, |b| {
                match &b.up.pack {
                    Pack::Dense(w) => w.cols,
                    Pack::Tw(p) => p.n,
                    Pack::Tvw(p) => p.n,
                    Pack::Vw24(p) => p.n,
                }
            });
            (self.dims.d_model, d_ff)
        };
        NativeModel {
            dims: self.dims,
            nets: self.nets.clone(),
            intra,
            x: Matrix::zeros(tokens, d_model),
            h: Matrix::zeros(tokens, d_ff.max(1)),
            t: Matrix::zeros(tokens, d_model),
            pooled: Matrix::zeros(self.dims.batch, d_model),
            logits: Matrix::zeros(self.dims.batch, self.dims.n_classes),
        }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn load(&self) -> Result<Box<dyn PreparedModel>> {
        Ok(Box::new(self.load_native(None)))
    }

    fn load_with_intra(&self, intra: Option<Arc<ThreadPool>>) -> Result<Box<dyn PreparedModel>> {
        Ok(Box::new(self.load_native(intra)))
    }
}

/// Per-worker model instance: shared packed weights + private scratch.
struct NativeModel {
    dims: ModelDims,
    nets: Arc<Vec<VariantNet>>,
    /// Shared intra-op kernel pool ([`Backend::load_with_intra`]); the
    /// parallel kernel paths claim disjoint output chunks from it.  None:
    /// serial kernels at their tuned/default tile configs.
    intra: Option<Arc<ThreadPool>>,
    x: Matrix,
    h: Matrix,
    t: Matrix,
    pooled: Matrix,
    logits: Matrix,
}

/// Dispatch one packed GEMM into `c` (fully overwritten).  With an
/// intra-op pool, each kernel family runs its pool-parallel path —
/// row bands (dense), condensed-tile ranges (TW/TVW), column blocks
/// (2:4) — and each falls back to the serial tuned-config kernel when
/// the problem is too small to split (the kernels report the fallback;
/// here the dispatch simply trusts their effective-threads logic).
fn gemm_into(a: &Matrix, g: &PackedGemm, c: &mut Matrix, intra: Option<&ThreadPool>) {
    let threads = intra.map_or(1, ThreadPool::threads);
    match &g.pack {
        Pack::Dense(w) => {
            if let Some(pool) = intra.filter(|_| threads > 1) {
                matmul_parallel_into(a, w, c, &g.cfg, threads, pool);
            } else {
                matmul_tiled_into(a, w, c, &g.cfg);
            }
        }
        Pack::Tw(p) => {
            // the TW scatter only writes kept output columns; clear the rest
            c.data.fill(0.0);
            if let Some(pool) = intra.filter(|_| threads > 1) {
                tw_matmul_parallel_into(a, p, c, &g.cfg, threads, pool);
            } else {
                tw_matmul_into_with(a, p, c, &g.cfg);
            }
        }
        Pack::Tvw(p) => {
            if let Some(pool) = intra.filter(|_| threads > 1) {
                tvw_matmul_parallel_into(a, p, c, &g.cfg, threads, pool);
            } else {
                tvw_matmul_into_with(a, p, c, &g.cfg);
            }
        }
        Pack::Vw24(p) => {
            if let Some(pool) = intra.filter(|_| threads > 1) {
                vw24_matmul_parallel_into(a, p, c, &g.cfg, threads, pool);
            } else {
                vw24_matmul_into_with(a, p, c, &g.cfg);
            }
        }
    }
}

impl PreparedModel for NativeModel {
    fn dims(&self) -> ModelDims {
        self.dims
    }

    fn variants(&self) -> Vec<String> {
        self.nets.iter().map(|n| n.name.clone()).collect()
    }

    fn run(&mut self, variant: &str, packed: &[f32]) -> Result<Vec<f32>> {
        let nets = self.nets.clone();
        let net = nets
            .iter()
            .find(|n| n.name == variant)
            .ok_or_else(|| anyhow!("variant {variant:?} not packed in the native backend"))?;
        let want = self.dims.batch * self.dims.per_request_len();
        ensure!(
            packed.len() == want,
            "packed batch has {} floats, native model expects {want}",
            packed.len()
        );
        self.x.data.copy_from_slice(packed);
        let intra = self.intra.as_deref();
        for block in &net.blocks {
            gemm_into(&self.x, &block.up, &mut self.h, intra);
            for v in &mut self.h.data {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            gemm_into(&self.h, &block.down, &mut self.t, intra);
            // residual keeps activations O(1) through the stack
            for (xv, tv) in self.x.data.iter_mut().zip(&self.t.data) {
                *xv += tv;
            }
        }
        // mean-pool each request's seq tokens, then the dense head
        let (batch, seq) = (self.dims.batch, self.dims.seq);
        let inv = 1.0 / seq as f32;
        for b in 0..batch {
            let dst = self.pooled.row_mut(b);
            dst.fill(0.0);
            for s_i in 0..seq {
                for (dv, sv) in dst.iter_mut().zip(self.x.row(b * seq + s_i)) {
                    *dv += sv;
                }
            }
            for dv in dst.iter_mut() {
                *dv *= inv;
            }
        }
        gemm_into(&self.pooled, &net.head, &mut self.logits, intra);
        Ok(self.logits.data.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::{PlanKey, TunedEntry};

    fn tiny_spec() -> NativeModelSpec {
        NativeModelSpec {
            seq: 4,
            d_model: 16,
            d_ff: 32,
            n_classes: 4,
            batch: 2,
            g: 8,
            ..NativeModelSpec::default()
        }
    }

    #[test]
    fn all_variants_run_and_are_finite() {
        let backend = NativeBackend::new(tiny_spec(), None).unwrap();
        let mut model = backend.load().unwrap();
        let dims = model.dims();
        let packed = vec![0.25f32; dims.batch * dims.per_request_len()];
        for variant in NATIVE_VARIANTS {
            let logits = model.run(variant, &packed).unwrap();
            assert_eq!(logits.len(), dims.batch * dims.n_classes, "{variant}");
            assert!(logits.iter().all(|v| v.is_finite()), "{variant}");
        }
    }

    #[test]
    fn deterministic_across_backend_instances() {
        let a = NativeBackend::new(tiny_spec(), None).unwrap();
        let b = NativeBackend::new(tiny_spec(), None).unwrap();
        let mut ma = a.load().unwrap();
        let mut mb = b.load().unwrap();
        let dims = ma.dims();
        let packed: Vec<f32> = (0..dims.batch * dims.per_request_len())
            .map(|i| (i % 7) as f32 * 0.1 - 0.3)
            .collect();
        for variant in ["model_dense", "model_tw", "model_tvw"] {
            assert_eq!(ma.run(variant, &packed).unwrap(), mb.run(variant, &packed).unwrap());
        }
    }

    #[test]
    fn sparse_variants_diverge_from_dense() {
        // pruning must actually change the computation
        let backend = NativeBackend::new(tiny_spec(), None).unwrap();
        let mut model = backend.load().unwrap();
        let dims = model.dims();
        let packed: Vec<f32> = (0..dims.batch * dims.per_request_len())
            .map(|i| ((i * 13 % 11) as f32 - 5.0) * 0.1)
            .collect();
        let dense = model.run("model_dense", &packed).unwrap();
        let tw = model.run("model_tw", &packed).unwrap();
        assert!(dense.iter().zip(&tw).any(|(a, b)| (a - b).abs() > 1e-6));
    }

    #[test]
    fn unknown_variant_is_an_error() {
        let backend = NativeBackend::new(tiny_spec(), None).unwrap();
        let mut model = backend.load().unwrap();
        let dims = model.dims();
        let packed = vec![0.0f32; dims.batch * dims.per_request_len()];
        assert!(model.run("model_bogus", &packed).is_err());
        assert!(model.run("model_dense", &packed[1..]).is_err());
    }

    #[test]
    fn plan_cache_overrides_tile_config() {
        // a cache entry for the up-GEMM shape must be resolved; wrong tile
        // configs cannot change the numerics, so check via tile_config()
        let spec = tiny_spec();
        let tokens = spec.batch * spec.seq;
        let shape = GemmShape::new(tokens, spec.d_model, spec.d_ff);
        let mut cache = PlanCache::new();
        cache.insert(TunedEntry {
            key: PlanKey::new(shape, "TW", spec.sparsity, 1),
            variant: "tw-fused".into(),
            bm: 7,
            bk: 64,
            g: 8,
            threads: 1,
            measured_us: 1.0,
            model_us: 1.0,
            default_us: 2.0,
        });
        assert_eq!(
            cache.tile_config(shape, "TW", spec.sparsity, 1),
            Some(TileConfig::new(7, 64))
        );
        let cache = Arc::new(cache);
        let with = NativeBackend::new(spec.clone(), Some(cache)).unwrap();
        let without = NativeBackend::new(spec, None).unwrap();
        let mut ma = with.load().unwrap();
        let mut mb = without.load().unwrap();
        let dims = ma.dims();
        let packed = vec![0.5f32; dims.batch * dims.per_request_len()];
        // tile config is perf-only: tuned and default execution agree
        let a = ma.run("model_tw", &packed).unwrap();
        let b = mb.run("model_tw", &packed).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn intra_pool_matches_serial_logits() {
        // the pooled kernel paths are a scheduling change, not a numeric
        // one: every variant must agree with the serial instance
        let backend = NativeBackend::new(tiny_spec(), None).unwrap();
        let mut serial = backend.load().unwrap();
        let pool = Arc::new(crate::pool::ThreadPool::new(4));
        let mut pooled = backend.load_with_intra(Some(pool)).unwrap();
        let dims = serial.dims();
        let packed: Vec<f32> = (0..dims.batch * dims.per_request_len())
            .map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.05)
            .collect();
        for variant in NATIVE_VARIANTS {
            let a = serial.run(variant, &packed).unwrap();
            let b = pooled.run(variant, &packed).unwrap();
            assert_eq!(a.len(), b.len(), "{variant}");
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-3, "{variant}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn bert_base_spec_matches_model_zoo() {
        let spec = NativeModelSpec::bert_base(4, 8);
        assert_eq!(spec.d_model, 768);
        assert_eq!(spec.d_ff, 3072);
        assert_eq!(spec.batch, 4);
    }
}
