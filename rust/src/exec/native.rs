//! Native in-process backend: the serving path that runs the paper's
//! kernels for real, with zero external dependencies.
//!
//! Since the layer-graph IR landed (`docs/DESIGN.md` §6) this backend is
//! a thin adapter: the residual-MLP classifier it has always served
//! (transformer-encoder shaped per-block `d_model -> d_ff -> d_model`
//! GEMMs plus a dense head — the FFN pair that dominates BERT FLOPs) is
//! just another compiled [`crate::graph::GraphProgram`], built through
//! [`crate::graph::GraphBuilder`] and executed by
//! [`crate::graph::GraphModel`] like every zoo model.  Each serving
//! variant packs every prunable layer **once** at construction:
//!
//! - `model_dense` — raw row-major weights (`gemm::matmul_tiled_into`)
//! - `model_tw`    — TW-pruned `sparse::TwPlan` condensed tiles
//!   (fused-CTO `gemm::tw_matmul_into_scratch`)
//! - `model_tvw`   — TVW-pruned `sparse::TvwPlan` (CTO + 2:4 metadata)
//! - `model_vw24`  — plain 2:4 along K, `sparse::Vw24Plan`
//!
//! Per-GEMM [`crate::gemm::TileConfig`]s are resolved from the autotune
//! [`PlanCache`] when one is supplied.  The packed programs live behind
//! an `Arc`, so a pool of N workers shares one copy of the weights; only
//! the per-worker workspace arena is duplicated, and the request hot loop
//! performs no allocation beyond the response vector.

use std::sync::Arc;

use super::{Backend, ModelDims, PreparedModel};
use crate::autotune::PlanCache;
use crate::error::Result;
use crate::graph::{
    Act, CompileOptions, GraphBuilder, GraphModel, GraphPattern, GraphProgram, Op, PackOptions,
};
use crate::pool::ThreadPool;
use crate::tensor::Matrix;
use crate::util::Rng;
use crate::{bail, ensure};

/// Shape + pruning recipe of the native model.  Weights are generated
/// deterministically from `seed`, so every backend constructed from the
/// same spec serves identical logits.
#[derive(Clone, Debug)]
pub struct NativeModelSpec {
    pub seq: usize,
    pub d_model: usize,
    /// FFN hidden width (the `d_model -> d_ff -> d_model` block pair).
    pub d_ff: usize,
    pub n_classes: usize,
    /// Residual FFN blocks stacked before the classifier head.
    pub n_layers: usize,
    /// Fixed serving batch (requests per invocation, padded).
    pub batch: usize,
    /// Target sparsity for the TW / TVW variants (TVW floors at 0.5).
    pub sparsity: f64,
    /// TW tile granularity G.
    pub g: usize,
    pub seed: u64,
    /// Graph-level epilogue fusion (`serve --no-fusion` clears it; the
    /// `PALLAS_NO_FUSION` env still applies when this stays true).
    pub fuse: bool,
    /// Which variants to pack (packing TW/TVW plans for large layers is
    /// the expensive part of construction; benches prune this list).
    pub variants: Vec<String>,
}

pub const NATIVE_VARIANTS: [&str; 4] = ["model_dense", "model_tw", "model_tvw", "model_vw24"];

impl Default for NativeModelSpec {
    /// A deliberately small "BERT-nano" so the native serving tests run in
    /// milliseconds: 2 blocks of 64 -> 128 -> 64 over 16-token requests.
    fn default() -> Self {
        NativeModelSpec {
            seq: 16,
            d_model: 64,
            d_ff: 128,
            n_classes: 8,
            n_layers: 2,
            batch: 8,
            sparsity: 0.75,
            g: 16,
            seed: 42,
            fuse: true,
            variants: NATIVE_VARIANTS.iter().map(|v| v.to_string()).collect(),
        }
    }
}

impl NativeModelSpec {
    /// BERT-base FFN geometry (the paper's dominant GEMMs), with the
    /// widths taken from the `models` zoo so the bench and the simulator
    /// agree on what "BERT-base shapes" means.  `seq` stays a parameter:
    /// serving latency is linear in tokens and benches trim it.
    pub fn bert_base(batch: usize, seq: usize) -> NativeModelSpec {
        let bert = crate::models::bert_base(batch, seq);
        let ffn1 = bert
            .layers
            .iter()
            .find(|l| l.name == "ffn1")
            .expect("bert_base workload has an ffn1 layer");
        NativeModelSpec {
            seq,
            d_model: ffn1.shape.k,
            d_ff: ffn1.shape.n,
            n_classes: 2,
            n_layers: 1,
            batch,
            sparsity: 0.75,
            g: 64,
            seed: 42,
            ..NativeModelSpec::default()
        }
    }

    /// Restrict which variants get packed.
    pub fn with_variants(mut self, variants: &[&str]) -> NativeModelSpec {
        self.variants = variants.iter().map(|v| v.to_string()).collect();
        self
    }
}

/// Compile the residual-MLP spec into one variant's graph program — the
/// same builder path `graph::compile` uses for the zoo models.
fn residual_mlp_program(
    spec: &NativeModelSpec,
    variant: &str,
    cache: Option<&Arc<PlanCache>>,
) -> Result<GraphProgram> {
    let Some(pattern) = GraphPattern::from_variant(variant) else {
        bail!("unknown native variant {variant:?} (expected {NATIVE_VARIANTS:?})");
    };
    let tokens = spec.batch * spec.seq;
    // one CompileOptions so packing resolution (pattern -> family,
    // prunable:false dense rule, plan-cache tile lookup) stays the single
    // implementation graph::compile uses for the zoo models
    let opts = CompileOptions {
        pattern,
        pack: PackOptions { sparsity: spec.sparsity, g: spec.g, ..PackOptions::default() },
        seed: spec.seed,
        plan_cache: cache.cloned(),
        model_key: Some("residual-mlp".into()),
        ..CompileOptions::default()
    };
    let mut rng = Rng::new(spec.seed);

    let mut b = GraphBuilder::new();
    let x = b.buffer(tokens, spec.d_model);
    let h = b.buffer(tokens, spec.d_ff);
    let t = b.buffer(tokens, spec.d_model);
    // token-resident buffers shrink with the effective batch (seq rows per
    // request); per-bucket tile plans probe the cache at each bucket's M
    for id in [x, h, t] {
        b.scale_by_batch(id, spec.seq);
    }
    let head_buckets = crate::graph::batch_buckets(spec.batch);
    let token_buckets: Vec<usize> = head_buckets.iter().map(|&bb| bb * spec.seq).collect();

    for layer in 0..spec.n_layers {
        let w_up = Matrix::randn(spec.d_model, spec.d_ff, &mut rng);
        let w_down = Matrix::randn(spec.d_ff, spec.d_model, &mut rng);
        let node = opts.pack_layer(
            "residual-mlp",
            &format!("l{layer}.up"),
            &w_up,
            tokens,
            &token_buckets,
            true,
        )?;
        b.gemm_into(x, node, h);
        b.push(Op::BiasAct { buf: h, bias: None, act: Some(Act::Relu) });
        let node = opts.pack_layer(
            "residual-mlp",
            &format!("l{layer}.down"),
            &w_down,
            tokens,
            &token_buckets,
            true,
        )?;
        b.gemm_into(h, node, t);
        // residual keeps activations O(1) through the stack
        b.push(Op::Residual { src: t, dst: x });
    }

    let pooled = b.buffer(spec.batch, spec.d_model);
    b.scale_by_batch(pooled, 1);
    b.push(Op::MeanPool { input: x, out: pooled, seq: spec.seq });
    // the head stays dense regardless of variant — the paper's "keep the
    // small accuracy-critical layers dense" rule (prunable: false)
    let w_head = Matrix::randn(spec.d_model, spec.n_classes, &mut rng);
    let head = opts.pack_layer("residual-mlp", "head", &w_head, spec.batch, &head_buckets, false)?;
    let logits = b.gemm(pooled, head);

    let dims = ModelDims {
        batch: spec.batch,
        seq: spec.seq,
        d_model: spec.d_model,
        n_classes: spec.n_classes,
    };
    let mut p = b.finish("residual-mlp", variant, x, logits, dims);
    // this builder bypasses graph::compile, so it runs the fusion pass
    // itself; opts.fuse carries the PALLAS_NO_FUSION env default
    if opts.fuse && spec.fuse {
        crate::graph::fuse_program(&mut p);
    }
    Ok(p)
}

/// The shared, immutable packed model (compiled variant programs).
pub struct NativeBackend {
    dims: ModelDims,
    programs: Arc<Vec<GraphProgram>>,
    /// Per-node/per-op profiling sink shared by every model instance this
    /// backend loads; `None` (the default) keeps the hot path unprofiled.
    telemetry: Option<Arc<crate::telemetry::Telemetry>>,
}

impl NativeBackend {
    /// Build and pack the model.  `plan_cache` is the autotuner's output
    /// (`tilewise autotune --out plans.json`); absent, every kernel runs
    /// at its historical default tile config.
    pub fn new(spec: NativeModelSpec, plan_cache: Option<Arc<PlanCache>>) -> Result<NativeBackend> {
        ensure!(
            spec.seq > 0 && spec.d_model > 0 && spec.d_ff > 0 && spec.n_classes > 0,
            "native model spec has a zero dimension: {spec:?}"
        );
        ensure!(spec.n_layers > 0 && spec.batch > 0, "native model needs n_layers/batch >= 1");
        ensure!(!spec.variants.is_empty(), "native model spec packs no variants");
        let wants_24 = spec
            .variants
            .iter()
            .any(|v| v == "model_tvw" || v == "model_vw24");
        ensure!(
            !wants_24 || (spec.d_model % 4 == 0 && spec.d_ff % 4 == 0),
            "2:4 variants need d_model and d_ff to be multiples of 4 (got {} / {})",
            spec.d_model,
            spec.d_ff
        );

        let mut programs = Vec::with_capacity(spec.variants.len());
        for name in &spec.variants {
            programs.push(residual_mlp_program(&spec, name, plan_cache.as_ref())?);
        }
        let dims = programs[0].dims;
        Ok(NativeBackend { dims, programs: Arc::new(programs), telemetry: None })
    }

    pub fn dims(&self) -> ModelDims {
        self.dims
    }

    /// Turn on per-node/per-op profiling for every model instance this
    /// backend loads from here on, returning the shared sink.  Call
    /// before handing the backend to the server (i.e. before `Arc`-ing).
    pub fn enable_telemetry(&mut self) -> Arc<crate::telemetry::Telemetry> {
        let tele = Arc::new(crate::telemetry::Telemetry::new());
        self.telemetry = Some(tele.clone());
        tele
    }

    /// Build one per-worker model instance; `intra` is the shared intra-op
    /// kernel pool (None = serial kernels at their tuned/default configs).
    fn load_native(&self, intra: Option<Arc<ThreadPool>>) -> Result<GraphModel> {
        GraphModel::with_telemetry(self.programs.clone(), intra, self.telemetry.clone())
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn load(&self) -> Result<Box<dyn PreparedModel>> {
        Ok(Box::new(self.load_native(None)?))
    }

    fn load_with_intra(&self, intra: Option<Arc<ThreadPool>>) -> Result<Box<dyn PreparedModel>> {
        Ok(Box::new(self.load_native(intra)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::{PlanKey, TunedEntry};
    use crate::gemm::TileConfig;
    use crate::gpusim::GemmShape;

    fn tiny_spec() -> NativeModelSpec {
        NativeModelSpec {
            seq: 4,
            d_model: 16,
            d_ff: 32,
            n_classes: 4,
            batch: 2,
            g: 8,
            ..NativeModelSpec::default()
        }
    }

    #[test]
    fn all_variants_run_and_are_finite() {
        let backend = NativeBackend::new(tiny_spec(), None).unwrap();
        let mut model = backend.load().unwrap();
        let dims = model.dims();
        let packed = vec![0.25f32; dims.batch * dims.per_request_len()];
        for variant in NATIVE_VARIANTS {
            let logits = model.run(variant, &packed).unwrap();
            assert_eq!(logits.len(), dims.batch * dims.n_classes, "{variant}");
            assert!(logits.iter().all(|v| v.is_finite()), "{variant}");
        }
    }

    #[test]
    fn deterministic_across_backend_instances() {
        let a = NativeBackend::new(tiny_spec(), None).unwrap();
        let b = NativeBackend::new(tiny_spec(), None).unwrap();
        let mut ma = a.load().unwrap();
        let mut mb = b.load().unwrap();
        let dims = ma.dims();
        let packed: Vec<f32> = (0..dims.batch * dims.per_request_len())
            .map(|i| (i % 7) as f32 * 0.1 - 0.3)
            .collect();
        for variant in ["model_dense", "model_tw", "model_tvw"] {
            assert_eq!(ma.run(variant, &packed).unwrap(), mb.run(variant, &packed).unwrap());
        }
    }

    #[test]
    fn sparse_variants_diverge_from_dense() {
        // pruning must actually change the computation
        let backend = NativeBackend::new(tiny_spec(), None).unwrap();
        let mut model = backend.load().unwrap();
        let dims = model.dims();
        let packed: Vec<f32> = (0..dims.batch * dims.per_request_len())
            .map(|i| ((i * 13 % 11) as f32 - 5.0) * 0.1)
            .collect();
        let dense = model.run("model_dense", &packed).unwrap();
        let tw = model.run("model_tw", &packed).unwrap();
        assert!(dense.iter().zip(&tw).any(|(a, b)| (a - b).abs() > 1e-6));
    }

    #[test]
    fn unknown_variant_is_an_error() {
        let backend = NativeBackend::new(tiny_spec(), None).unwrap();
        let mut model = backend.load().unwrap();
        let dims = model.dims();
        let packed = vec![0.0f32; dims.batch * dims.per_request_len()];
        assert!(model.run("model_bogus", &packed).is_err());
        assert!(model.run("model_dense", &packed[1..]).is_err());
    }

    #[test]
    fn plan_cache_overrides_tile_config() {
        // a cache entry for the up-GEMM shape must be resolved; wrong tile
        // configs cannot change the numerics, so check via tile_config()
        let spec = tiny_spec();
        let tokens = spec.batch * spec.seq;
        let shape = GemmShape::new(tokens, spec.d_model, spec.d_ff);
        let mut cache = PlanCache::new();
        cache.insert(TunedEntry {
            key: PlanKey::new(shape, "TW", spec.sparsity, 1),
            variant: "tw-fused".into(),
            bm: 7,
            bk: 64,
            g: 8,
            threads: 1,
            micro: "auto".into(),
            precision: "fp32".into(),
            measured_us: 1.0,
            model_us: 1.0,
            default_us: 2.0,
        });
        assert_eq!(
            cache.tile_config(shape, "TW", spec.sparsity, 1),
            Some(TileConfig::new(7, 64))
        );
        let cache = Arc::new(cache);
        let with = NativeBackend::new(spec.clone(), Some(cache.clone())).unwrap();
        let without = NativeBackend::new(spec, None).unwrap();
        // the packed program must carry the tuned blocking
        let tuned = with
            .programs
            .iter()
            .find(|p| p.variant == "model_tw")
            .and_then(|p| p.weights.iter().find(|w| w.name == "l0.up"))
            .map(|w| w.cfg)
            .expect("tuned up-GEMM node");
        assert_eq!(tuned, TileConfig::new(7, 64));
        let mut ma = with.load().unwrap();
        let mut mb = without.load().unwrap();
        let dims = ma.dims();
        let packed = vec![0.5f32; dims.batch * dims.per_request_len()];
        // tile config is perf-only: tuned and default execution agree
        let a = ma.run("model_tw", &packed).unwrap();
        let b = mb.run("model_tw", &packed).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn intra_pool_matches_serial_logits() {
        // the pooled kernel paths are a scheduling change, not a numeric
        // one: every variant must agree with the serial instance
        let backend = NativeBackend::new(tiny_spec(), None).unwrap();
        let mut serial = backend.load().unwrap();
        let pool = Arc::new(crate::pool::ThreadPool::new(4));
        let mut pooled = backend.load_with_intra(Some(pool)).unwrap();
        let dims = serial.dims();
        let packed: Vec<f32> = (0..dims.batch * dims.per_request_len())
            .map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.05)
            .collect();
        for variant in NATIVE_VARIANTS {
            let a = serial.run(variant, &packed).unwrap();
            let b = pooled.run(variant, &packed).unwrap();
            assert_eq!(a.len(), b.len(), "{variant}");
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-3, "{variant}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn run_batch_prefix_matches_dedicated_small_batch() {
        // dynamic-M serving: m_eff real requests executed inside the
        // batch-B workspace must match a backend compiled at batch m_eff
        // (same seed -> identical weights), and a later full-batch run
        // through the same workspace must still be correct
        let big = NativeBackend::new(NativeModelSpec { batch: 4, ..tiny_spec() }, None).unwrap();
        let small = NativeBackend::new(NativeModelSpec { batch: 2, ..tiny_spec() }, None).unwrap();
        let mut mb = big.load().unwrap();
        let mut ms = small.load().unwrap();
        let prl = mb.dims().per_request_len();
        let full: Vec<f32> = (0..4 * prl).map(|i| ((i * 5 % 17) as f32 - 8.0) * 0.07).collect();
        for variant in NATIVE_VARIANTS {
            let want_full = mb.run(variant, &full).unwrap();
            let got = mb.run_batch(variant, &full[..2 * prl], 2).unwrap();
            let want = ms.run(variant, &full[..2 * prl]).unwrap();
            assert_eq!(got.len(), want.len(), "{variant}");
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "{variant}: {a} vs {b}");
            }
            // the workspace regrows to the full batch with no state leak
            let again = mb.run(variant, &full).unwrap();
            assert_eq!(want_full, again, "{variant}: full batch after shrink");
        }
        // contract violations are errors, not panics
        assert!(mb.run_batch("model_dense", &full[..prl], 0).is_err());
        assert!(mb.run_batch("model_dense", &full[..prl], 5).is_err());
        assert!(mb.run_batch("model_dense", &full[..prl + 1], 1).is_err());
    }

    #[test]
    fn bert_base_spec_matches_model_zoo() {
        let spec = NativeModelSpec::bert_base(4, 8);
        assert_eq!(spec.d_model, 768);
        assert_eq!(spec.d_ff, 3072);
        assert_eq!(spec.batch, 4);
    }
}
