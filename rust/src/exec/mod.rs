//! Backend-agnostic execution layer: the seam between the serving
//! coordinator and whatever actually runs a forward pass.
//!
//! The coordinator used to be hard-wired to the PJRT [`crate::runtime::Engine`],
//! which is a stub unless the `pjrt` feature (and the external `xla` crate)
//! is present — so the serving stack could never run without an artifacts
//! directory.  This layer splits "how a batch is executed" from "how
//! requests are batched and routed":
//!
//! - [`Backend`] — a factory the server holds by `Arc<dyn Backend>`; it is
//!   `Send + Sync` and cheap to share across worker threads.
//! - [`PreparedModel`] — one worker's loaded model instance.  Created by
//!   [`Backend::load`] *inside* the worker thread (the PJRT engine wraps
//!   `Rc` handles and is not `Send`), so it carries no `Send` bound and may
//!   own per-thread scratch buffers for an allocation-free hot loop.
//! - [`PjrtBackend`] — the original artifact path, adapting
//!   [`crate::runtime::Engine`]; degrades exactly as before when the
//!   feature or the artifacts are missing.
//! - [`NativeBackend`] — in-process execution through the real CPU kernels
//!   in [`crate::gemm`]: the residual-MLP surrogate compiled into a
//!   [`crate::graph::GraphProgram`] whose weights are pruned and packed
//!   once at load time into [`crate::sparse::TwPlan`] /
//!   [`crate::sparse::TvwPlan`] / [`crate::sparse::Vw24Plan`] condensed
//!   forms, per-layer [`crate::gemm::TileConfig`]s resolved from the
//!   autotune [`crate::autotune::PlanCache`] — no artifacts, no Python,
//!   no feature gate.
//! - [`ZooBackend`] — any `models::` zoo workload (BERT encoder, VGG conv
//!   chain, NMT stacked LSTM) compiled through `graph::compile` and
//!   served the same way: per-layer packed sparse weights, workspace-
//!   arena execution, shared intra-op pool.
//!
//! See `docs/DESIGN.md` §5 (worker pool) and §6 (layer-graph IR).

pub mod native;
pub mod pjrt;
pub mod zoo;

pub use native::{NativeBackend, NativeModelSpec, NATIVE_VARIANTS};
pub use pjrt::PjrtBackend;
pub use zoo::{ZooBackend, ZooSpec};

use std::sync::Arc;

use crate::error::Result;
use crate::pool::ThreadPool;

/// Fixed batch geometry of a prepared model — the serving analogue of the
/// AOT `meta.json` header (shapes are static; the batcher pads to `batch`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDims {
    /// Fixed executable batch size (requests per invocation, padded).
    pub batch: usize,
    /// Sequence length of one request's activations.
    pub seq: usize,
    /// Model width; one request carries `seq * d_model` floats.
    pub d_model: usize,
    /// Logits per request.
    pub n_classes: usize,
}

impl ModelDims {
    /// Floats one request contributes to the packed batch tensor.
    pub fn per_request_len(&self) -> usize {
        self.seq * self.d_model
    }
}

/// A source of executable models.  The server shares one backend across
/// its worker pool; each worker calls [`Backend::load`] once, from its own
/// thread, and owns the returned [`PreparedModel`] for its lifetime.
pub trait Backend: Send + Sync {
    /// Short label for logs and the serve CLI ("pjrt" / "native").
    fn name(&self) -> &'static str;

    /// Prepare one model instance for the calling thread.  Heavyweight
    /// one-time work (weight packing, artifact compilation) belongs in the
    /// backend's constructor so N workers don't repeat it; `load` should
    /// only materialise per-thread state.
    fn load(&self) -> Result<Box<dyn PreparedModel>>;

    /// Like [`Backend::load`], but hands the instance a shared intra-op
    /// thread pool: per-GEMM work (row bands, condensed tiles, column
    /// blocks) is claimed from `intra` while inter-request parallelism
    /// stays with the coordinator's worker pool — the two-level model of
    /// `docs/DESIGN.md` §5.  Backends without intra-op support (PJRT owns
    /// its own runtime) ignore the pool.
    fn load_with_intra(&self, intra: Option<Arc<ThreadPool>>) -> Result<Box<dyn PreparedModel>> {
        let _ = intra;
        self.load()
    }
}

/// One worker's loaded model: executes padded batches by variant name.
/// Not `Send` by design — see [`Backend::load`].
pub trait PreparedModel {
    fn dims(&self) -> ModelDims;

    /// Variant names this model can serve ("model_dense" / "model_tw" /
    /// "model_tvw" / ...), matching the router's vocabulary.
    fn variants(&self) -> Vec<String>;

    /// Execute one padded batch: `packed` is the flat
    /// `(batch, seq * d_model)` tensor from `coordinator::pack_batch`;
    /// the result is the flat `(batch, n_classes)` logits.  `&mut self`
    /// lets implementations reuse scratch buffers across invocations.
    fn run(&mut self, variant: &str, packed: &[f32]) -> Result<Vec<f32>>;
}
