//! Backend-agnostic execution layer: the seam between the serving
//! coordinator and whatever actually runs a forward pass.
//!
//! The coordinator used to be hard-wired to the PJRT [`crate::runtime::Engine`],
//! which is a stub unless the `pjrt` feature (and the external `xla` crate)
//! is present — so the serving stack could never run without an artifacts
//! directory.  This layer splits "how a batch is executed" from "how
//! requests are batched and routed":
//!
//! - [`Backend`] — a factory the server holds by `Arc<dyn Backend>`; it is
//!   `Send + Sync` and cheap to share across worker threads.
//! - [`PreparedModel`] — one worker's loaded model instance.  Created by
//!   [`Backend::load`] *inside* the worker thread (the PJRT engine wraps
//!   `Rc` handles and is not `Send`), so it carries no `Send` bound and may
//!   own per-thread scratch buffers for an allocation-free hot loop.
//! - [`PjrtBackend`] — the original artifact path, adapting
//!   [`crate::runtime::Engine`]; degrades exactly as before when the
//!   feature or the artifacts are missing.
//! - [`NativeBackend`] — in-process execution through the real CPU kernels
//!   in [`crate::gemm`]: the residual-MLP surrogate compiled into a
//!   [`crate::graph::GraphProgram`] whose weights are pruned and packed
//!   once at load time into [`crate::sparse::TwPlan`] /
//!   [`crate::sparse::TvwPlan`] / [`crate::sparse::Vw24Plan`] condensed
//!   forms, per-layer [`crate::gemm::TileConfig`]s resolved from the
//!   autotune [`crate::autotune::PlanCache`] — no artifacts, no Python,
//!   no feature gate.
//! - [`ZooBackend`] — any `models::` zoo workload (BERT encoder, VGG conv
//!   chain, NMT stacked LSTM) compiled through `graph::compile` and
//!   served the same way: per-layer packed sparse weights, workspace-
//!   arena execution, shared intra-op pool.
//!
//! See `docs/DESIGN.md` §5 (worker pool) and §6 (layer-graph IR).

pub mod native;
pub mod pjrt;
pub mod zoo;

pub use native::{NativeBackend, NativeModelSpec, NATIVE_VARIANTS};
pub use pjrt::PjrtBackend;
pub use zoo::{ZooBackend, ZooSpec};

use std::sync::Arc;

use crate::error::Result;
use crate::pool::ThreadPool;
use crate::{bail, ensure};

/// Batch geometry of a prepared model — the serving analogue of the AOT
/// `meta.json` header.  `batch` is the **maximum** executable batch: the
/// workspace/artifact is sized for it at load time, and a dynamic-batch
/// invocation ([`PreparedModel::run_batch`]) executes any real batch
/// `m_eff <= batch` over the same prepared state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDims {
    /// Maximum executable batch size (requests per invocation; the
    /// compile-time B the workspace is sized for).
    pub batch: usize,
    /// Sequence length of one request's activations.
    pub seq: usize,
    /// Model width; one request carries `seq * d_model` floats.
    pub d_model: usize,
    /// Logits per request.
    pub n_classes: usize,
}

impl ModelDims {
    /// Floats one request contributes to the packed batch tensor.
    pub fn per_request_len(&self) -> usize {
        self.seq * self.d_model
    }

    /// Packed-tensor length for `m_eff` real requests (the dynamic-batch
    /// input contract of [`PreparedModel::run_batch`]).
    pub fn packed_len(&self, m_eff: usize) -> usize {
        m_eff * self.per_request_len()
    }
}

/// A source of executable models.  The server shares one backend across
/// its worker pool; each worker calls [`Backend::load`] once, from its own
/// thread, and owns the returned [`PreparedModel`] for its lifetime.
pub trait Backend: Send + Sync {
    /// Short label for logs and the serve CLI ("pjrt" / "native").
    fn name(&self) -> &'static str;

    /// Prepare one model instance for the calling thread.  Heavyweight
    /// one-time work (weight packing, artifact compilation) belongs in the
    /// backend's constructor so N workers don't repeat it; `load` should
    /// only materialise per-thread state.
    fn load(&self) -> Result<Box<dyn PreparedModel>>;

    /// Like [`Backend::load`], but hands the instance a shared intra-op
    /// thread pool: per-GEMM work (row bands, condensed tiles, column
    /// blocks) is claimed from `intra` while inter-request parallelism
    /// stays with the coordinator's worker pool — the two-level model of
    /// `docs/DESIGN.md` §5.  Backends without intra-op support (PJRT owns
    /// its own runtime) ignore the pool.
    fn load_with_intra(&self, intra: Option<Arc<ThreadPool>>) -> Result<Box<dyn PreparedModel>> {
        let _ = intra;
        self.load()
    }
}

/// One worker's loaded model: executes batches by variant name — the
/// full padded batch ([`PreparedModel::run`]) or the dynamic effective
/// batch ([`PreparedModel::run_batch`]).  Not `Send` by design — see
/// [`Backend::load`].
pub trait PreparedModel {
    fn dims(&self) -> ModelDims;

    /// Variant names this model can serve ("model_dense" / "model_tw" /
    /// "model_tvw" / ...), matching the router's vocabulary.
    fn variants(&self) -> Vec<String>;

    /// Execute one full padded batch: `packed` is the flat
    /// `(batch, seq * d_model)` tensor from `coordinator::pack_batch`;
    /// the result is the flat `(batch, n_classes)` logits.  `&mut self`
    /// lets implementations reuse scratch buffers across invocations.
    fn run(&mut self, variant: &str, packed: &[f32]) -> Result<Vec<f32>>;

    /// Execute the **effective batch**: `packed` holds exactly `m_eff`
    /// real requests (`m_eff * seq * d_model` floats, `1 <= m_eff <=
    /// dims().batch`) and the result is their `m_eff * n_classes` logits.
    ///
    /// `m_eff` is *advisory*: backends whose shapes are truly static (the
    /// PJRT AOT artifacts) keep padded semantics behind this same API —
    /// the default implementation zero-pads the prefix back to the full
    /// batch, runs [`PreparedModel::run`], and trims the logits, which is
    /// numerically identical to what the coordinator always did.  Dynamic
    /// backends ([`crate::graph::GraphModel`]) override it to run compute
    /// proportional to the real rows, and advertise that via
    /// [`PreparedModel::supports_dynamic_batch`] so the coordinator can
    /// skip the pack-then-repad detour on static backends.
    fn run_batch(&mut self, variant: &str, packed: &[f32], m_eff: usize) -> Result<Vec<f32>> {
        let dims = self.dims();
        ensure!(
            m_eff >= 1 && m_eff <= dims.batch,
            "effective batch {m_eff} outside 1..={}",
            dims.batch
        );
        ensure!(
            packed.len() == dims.packed_len(m_eff),
            "packed batch has {} floats, expected {} for {m_eff} request(s)",
            packed.len(),
            dims.packed_len(m_eff)
        );
        let mut logits = if m_eff == dims.batch {
            self.run(variant, packed)?
        } else {
            let mut padded = vec![0.0f32; dims.packed_len(dims.batch)];
            padded[..packed.len()].copy_from_slice(packed);
            self.run(variant, &padded)?
        };
        logits.truncate(m_eff * dims.n_classes);
        Ok(logits)
    }

    /// Whether [`PreparedModel::run_batch`] actually saves compute at
    /// partial batches.  `false` (the default, inherited by static-shape
    /// backends like PJRT) tells the coordinator to pack the full padded
    /// batch and call [`PreparedModel::run`] directly — same numerics,
    /// one allocation instead of the default `run_batch`'s pack-then-repad
    /// pair.  Dynamic backends override this to `true`.
    fn supports_dynamic_batch(&self) -> bool {
        false
    }

    /// Streaming decode capability.  `Some` advertises per-slot
    /// recurrent/KV state the coordinator's step-scheduler can admit
    /// sessions into; `None` (the default) means one-shot only and the
    /// other `decode_*` methods fail.
    fn decode_caps(&self) -> Option<DecodeCaps> {
        None
    }

    /// Admit a session into `slot` with its prompt (`prompt.len()` a
    /// positive multiple of `DecodeCaps::d_in`, at most `max_steps`
    /// rows).  The slot's state rows are reset; stepping begins on the
    /// next [`PreparedModel::decode_step`].
    fn decode_begin(&mut self, slot: usize, prompt: &[f32]) -> Result<()> {
        let _ = (slot, prompt);
        bail!("this backend does not support streaming decode")
    }

    /// Advance every resident slot by one step under `variant`,
    /// returning one [`StepOut`] per active slot.  All resident slots
    /// must share the variant (the row-wise step runs one variant's
    /// packed weights); an empty slot table returns an empty vec.
    fn decode_step(&mut self, variant: &str) -> Result<Vec<StepOut>> {
        let _ = variant;
        bail!("this backend does not support streaming decode")
    }

    /// Retire `slot` (idempotent), zeroing its state rows and freeing it
    /// for the next admission.
    fn decode_end(&mut self, slot: usize) -> Result<()> {
        let _ = slot;
        bail!("this backend does not support streaming decode")
    }

    /// Resident (admitted, not yet retired) decode slots.
    fn decode_active(&self) -> usize {
        0
    }

    /// Lowest free decode slot, if the model supports decode and one is
    /// available.
    fn decode_free_slot(&self) -> Option<usize> {
        None
    }
}

/// Decode capability advertisement (see [`PreparedModel::decode_caps`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeCaps {
    /// Concurrent sessions the per-slot state buffers are sized for
    /// (the decode analogue of [`ModelDims::batch`]).
    pub slots: usize,
    /// Per-slot step capacity: prompt rows + generated tokens may not
    /// exceed it (KV caches hold this many rows per slot).
    pub max_steps: usize,
    /// Floats per prompt row (one step consumes one `(d_in)` row).
    pub d_in: usize,
}

/// One slot's result from a decode step.
#[derive(Clone, Debug)]
pub struct StepOut {
    pub slot: usize,
    /// 0-based step index within the slot's session.
    pub step: usize,
    /// argmax of `logits` (the greedy next token).
    pub token: usize,
    /// True once the slot has consumed its whole prompt — the logits of
    /// the step where this first turns true are the one-shot-parity
    /// logits.
    pub prompt_done: bool,
    pub logits: Vec<f32>,
}
