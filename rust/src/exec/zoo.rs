//! Zoo serving backend: any `models::` workload compiled into the
//! layer-graph IR and served through the same `Backend`/`PreparedModel`
//! seam as the PJRT and native backends — `serve --backend native
//! --model bert|vgg|nmt` runs a *real* BERT encoder / VGG conv chain /
//! stacked-LSTM NMT through the tuned TW/TVW/2:4 kernels, per-layer
//! packed weights, and the shared intra-op thread pool.

use std::sync::Arc;

use super::{Backend, ModelDims, PreparedModel};
use crate::autotune::PlanCache;
use crate::error::Result;
use crate::graph::{
    compile, compile_decode_set, CompileOptions, DecodeSet, GraphModel, GraphPattern, GraphProgram,
    PackOptions,
};
use crate::models::{self, ModelWorkload};
use crate::pool::ThreadPool;
use crate::quant::Precision;
use crate::{bail, ensure};

/// Which zoo model to serve, at serving-sized dims.  The defaults keep a
/// single batch in the low-hundreds-of-MFLOP range so a CPU worker turns
/// requests around in tens of milliseconds; the paper-scale dims remain
/// available through the `models::` constructors.
#[derive(Clone, Debug)]
pub struct ZooSpec {
    /// "bert" | "vgg" | "nmt" | "decoder".
    pub model: String,
    /// Requests per invocation (transformer/LSTM; conv models serve 1).
    pub batch: usize,
    /// Transformer tokens per request / LSTM unroll steps.
    pub seq: usize,
    /// Transformer d_model (d_ff = 4x) / LSTM hidden width.
    pub width: usize,
    /// Transformer encoder blocks.
    pub n_layers: usize,
    /// Attention heads (must divide `width`).
    pub heads: usize,
    /// Transformer classifier width.
    pub n_classes: usize,
    /// VGG input resolution (multiple of 32) and channel divisor.
    pub img: usize,
    pub width_div: usize,
    /// VGG FC width (replaces the 4096 pair at reduced scale).
    pub fc_dim: usize,
    pub sparsity: f64,
    pub g: usize,
    /// Numeric precision every layer packs at (`serve --precision`):
    /// `Fp32`, `Int8` (quantize-at-pack), or `Auto` (ask the plan cache
    /// per layer shape, f32 for untuned shapes).
    pub precision: Precision,
    /// Graph-level epilogue fusion (`serve --no-fusion` clears it; the
    /// `PALLAS_NO_FUSION` env still applies when this stays true).
    pub fuse: bool,
    pub seed: u64,
    /// Per-slot decode capacity in steps (prompt rows + generated tokens)
    /// for streaming-capable models (nmt, decoder); sizes the KV caches.
    pub max_steps: usize,
    /// Which variants to compile ("model_dense" / "model_tw" /
    /// "model_tvw" / "model_vw24" / "model_auto").
    pub variants: Vec<String>,
}

impl ZooSpec {
    /// Serving defaults for one zoo model name.
    pub fn for_model(model: &str) -> Result<ZooSpec> {
        let base = ZooSpec {
            model: model.to_string(),
            batch: 4,
            seq: 16,
            width: 256,
            n_layers: 2,
            heads: 4,
            n_classes: 8,
            img: 32,
            width_div: 4,
            fc_dim: 256,
            sparsity: 0.75,
            g: 32,
            precision: Precision::Fp32,
            fuse: true,
            seed: 42,
            max_steps: 32,
            variants: vec!["model_dense".into(), "model_tw".into(), "model_tvw".into()],
        };
        Ok(match model {
            "bert" => base,
            "vgg" | "vgg16" => ZooSpec { model: "vgg".into(), batch: 1, ..base },
            "nmt" => ZooSpec { batch: 8, seq: 8, width: 128, ..base },
            "decoder" => ZooSpec { model: "decoder".into(), n_classes: 16, ..base },
            other => bail!("unknown zoo model {other:?} (expected bert|vgg|nmt|decoder)"),
        })
    }

    /// The name the autotune CLI tunes this model under — the plan-cache
    /// key for recommendations and `Policy::Tuned` ("vgg" serves the
    /// workload `autotune --model vgg16` tunes).
    pub fn cache_key(&self) -> &str {
        match self.model.as_str() {
            "vgg" => "vgg16",
            other => other,
        }
    }

    pub fn with_variants(mut self, variants: &[&str]) -> ZooSpec {
        self.variants = variants.iter().map(|v| v.to_string()).collect();
        self
    }

    /// The scaled workload this spec compiles.
    pub fn workload(&self) -> Result<ModelWorkload> {
        Ok(match self.model.as_str() {
            "bert" => models::bert_at(self.batch, self.seq, self.width, self.n_layers),
            "vgg" => models::vgg16_scaled(self.img, self.width_div, self.fc_dim),
            "nmt" => models::nmt_at(self.batch, self.width, self.seq),
            "decoder" => models::decoder_at(self.batch, self.seq, self.width, self.n_layers),
            other => bail!("unknown zoo model {other:?} (expected bert|vgg|nmt|decoder)"),
        })
    }

    /// Whether this model has a streaming-decode topology (per-slot
    /// recurrent or KV state a step program can carry across steps).
    pub fn supports_decode(&self) -> bool {
        matches!(self.model.as_str(), "nmt" | "decoder")
    }

    fn compile_options(&self, plan_cache: Option<Arc<PlanCache>>) -> CompileOptions {
        CompileOptions {
            pattern: GraphPattern::Dense, // per-variant override below
            pack: PackOptions { sparsity: self.sparsity, g: self.g, precision: self.precision },
            seq: self.seq,
            heads: self.heads,
            n_classes: self.n_classes,
            // the decoder zoo model is the causal/streaming topology; its
            // one-shot forward reads the last position so streamed decode
            // has an exact parity twin
            causal: self.model == "decoder",
            // the env escape hatch still wins when the spec leaves fusion on
            fuse: self.fuse && CompileOptions::default().fuse,
            seed: self.seed,
            plan_cache,
            // Auto-pattern lookups must use the name the autotune CLI
            // tuned under ("bert", "vgg16"), not the workload display name
            model_key: Some(self.cache_key().to_string()),
        }
    }
}

/// The shared compiled model: one graph program per serving variant,
/// `Arc`-shared across the worker pool.
pub struct ZooBackend {
    dims: ModelDims,
    programs: Arc<Vec<GraphProgram>>,
    /// Streaming-decode half (step programs + token embedding) for models
    /// with a decode topology; `None` = one-shot only.  Compiled once and
    /// `Arc`-shared; each loaded model instance owns its own engine state.
    decode: Option<Arc<DecodeSet>>,
    /// Per-node/per-op profiling sink shared by every model instance this
    /// backend loads; `None` (the default) keeps the hot path unprofiled.
    telemetry: Option<Arc<crate::telemetry::Telemetry>>,
}

impl ZooBackend {
    pub fn new(spec: ZooSpec, plan_cache: Option<Arc<PlanCache>>) -> Result<ZooBackend> {
        ensure!(!spec.variants.is_empty(), "zoo spec compiles no variants");
        let workload = spec.workload()?;
        let opts = spec.compile_options(plan_cache);
        let mut programs = Vec::with_capacity(spec.variants.len());
        let mut patterns = Vec::with_capacity(spec.variants.len());
        for name in &spec.variants {
            let Some(pattern) = GraphPattern::from_variant(name) else {
                bail!("unknown zoo variant {name:?}");
            };
            programs.push(compile(&workload, &opts.with_pattern(pattern))?);
            patterns.push(pattern);
        }
        let decode = if spec.supports_decode() {
            Some(Arc::new(compile_decode_set(&workload, &opts, &patterns, spec.max_steps)?))
        } else {
            None
        };
        let dims = programs[0].dims;
        Ok(ZooBackend { dims, programs: Arc::new(programs), decode, telemetry: None })
    }

    pub fn dims(&self) -> ModelDims {
        self.dims
    }

    /// The compiled programs (benches build `GraphModel`s directly).
    pub fn programs(&self) -> Arc<Vec<GraphProgram>> {
        self.programs.clone()
    }

    /// The compiled decode half, when the model has one (benches drive
    /// `graph::DecodeEngine` directly for scheduler-free step timing).
    pub fn decode_set(&self) -> Option<Arc<DecodeSet>> {
        self.decode.clone()
    }

    /// Turn on per-node/per-op profiling for every model instance this
    /// backend loads from here on, returning the shared sink.  Call
    /// before handing the backend to the server (i.e. before `Arc`-ing).
    pub fn enable_telemetry(&mut self) -> Arc<crate::telemetry::Telemetry> {
        let tele = Arc::new(crate::telemetry::Telemetry::new());
        self.telemetry = Some(tele.clone());
        tele
    }

    fn load_graph(&self, intra: Option<Arc<ThreadPool>>) -> Result<GraphModel> {
        let mut model =
            GraphModel::with_telemetry(self.programs.clone(), intra, self.telemetry.clone())?;
        if let Some(set) = &self.decode {
            model.attach_decode(set.clone())?;
        }
        Ok(model)
    }
}

impl Backend for ZooBackend {
    fn name(&self) -> &'static str {
        "graph-zoo"
    }

    fn load(&self) -> Result<Box<dyn PreparedModel>> {
        Ok(Box::new(self.load_graph(None)?))
    }

    fn load_with_intra(&self, intra: Option<Arc<ThreadPool>>) -> Result<Box<dyn PreparedModel>> {
        Ok(Box::new(self.load_graph(intra)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(model: &str) -> ZooSpec {
        let mut spec = ZooSpec::for_model(model).unwrap();
        spec.batch = spec.batch.min(2);
        spec.seq = 4;
        spec.width = 16;
        spec.n_layers = 1;
        spec.n_classes = 4;
        spec.width_div = 16;
        spec.fc_dim = 32;
        spec.g = 8;
        spec
    }

    #[test]
    fn all_zoo_models_serve_all_variants() {
        for model in ["bert", "vgg", "nmt"] {
            let spec = tiny(model).with_variants(&["model_dense", "model_tw", "model_tvw"]);
            let backend = ZooBackend::new(spec, None).unwrap();
            let mut m = backend.load().unwrap();
            let dims = m.dims();
            let packed: Vec<f32> = (0..dims.batch * dims.per_request_len())
                .map(|i| ((i % 9) as f32 - 4.0) * 0.1)
                .collect();
            for variant in ["model_dense", "model_tw", "model_tvw"] {
                let logits = m.run(variant, &packed).unwrap();
                assert_eq!(logits.len(), dims.batch * dims.n_classes, "{model}/{variant}");
                assert!(logits.iter().all(|v| v.is_finite()), "{model}/{variant}");
            }
        }
    }

    #[test]
    fn all_zoo_models_serve_all_variants_at_int8() {
        // the tentpole end-to-end claim: every pattern runs at int8
        // through the same serving seam, and quantization error stays
        // small relative to the f32 twin's logits
        for model in ["bert", "nmt", "decoder"] {
            let mut spec = tiny(model).with_variants(&["model_dense", "model_tw", "model_tvw"]);
            spec.precision = Precision::Int8;
            let mut fp_spec = spec.clone();
            fp_spec.precision = Precision::Fp32;
            let mut q = ZooBackend::new(spec, None).unwrap().load().unwrap();
            let mut f = ZooBackend::new(fp_spec, None).unwrap().load().unwrap();
            let dims = q.dims();
            let packed: Vec<f32> = (0..dims.batch * dims.per_request_len())
                .map(|i| ((i % 9) as f32 - 4.0) * 0.1)
                .collect();
            for variant in ["model_dense", "model_tw", "model_tvw"] {
                let ql = q.run(variant, &packed).unwrap();
                let fl = f.run(variant, &packed).unwrap();
                assert_eq!(ql.len(), dims.batch * dims.n_classes, "{model}/{variant}");
                let scale =
                    fl.iter().fold(1.0f32, |a, &v| a.max(v.abs()));
                for (a, b) in ql.iter().zip(&fl) {
                    assert!(a.is_finite(), "{model}/{variant}");
                    assert!(
                        (a - b).abs() <= 0.12 * scale,
                        "{model}/{variant}: int8 {a} vs f32 {b} (scale {scale})"
                    );
                }
            }
        }
    }

    #[test]
    fn cache_key_maps_to_autotune_names() {
        // `autotune --model vgg16` writes its recommendation under
        // "vgg16"; serving `--model vgg` (or "vgg16") must look it up there
        assert_eq!(ZooSpec::for_model("vgg").unwrap().cache_key(), "vgg16");
        assert_eq!(ZooSpec::for_model("vgg16").unwrap().cache_key(), "vgg16");
        assert_eq!(ZooSpec::for_model("vgg16").unwrap().model, "vgg");
        assert_eq!(ZooSpec::for_model("bert").unwrap().cache_key(), "bert");
        assert_eq!(ZooSpec::for_model("nmt").unwrap().cache_key(), "nmt");
    }

    #[test]
    fn unknown_model_is_an_error() {
        assert!(ZooSpec::for_model("resnet99").is_err());
        let mut spec = tiny("bert");
        spec.model = "alexnet".into();
        assert!(ZooBackend::new(spec, None).is_err());
    }

    #[test]
    fn zoo_run_batch_matches_full_batch_prefix_semantics() {
        // the dynamic path must serve every zoo topology: a partial batch
        // through the batch-B workspace returns one logit row per real
        // request, matching a dedicated batch-m_eff compilation
        for model in ["bert", "nmt"] {
            let mut spec = tiny(model);
            spec.batch = 4;
            let backend = ZooBackend::new(spec.clone(), None).unwrap();
            let mut m = backend.load().unwrap();
            let dims = m.dims();
            let prl = dims.per_request_len();
            let x: Vec<f32> = (0..4 * prl).map(|i| ((i * 3 % 11) as f32 - 5.0) * 0.08).collect();
            let mut small_spec = spec.clone();
            small_spec.batch = 2;
            let small = ZooBackend::new(small_spec, None).unwrap();
            let mut sm = small.load().unwrap();
            for variant in ["model_dense", "model_tw", "model_tvw"] {
                let got = m.run_batch(variant, &x[..2 * prl], 2).unwrap();
                let want = sm.run(variant, &x[..2 * prl]).unwrap();
                assert_eq!(got.len(), 2 * dims.n_classes, "{model}/{variant}");
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-4, "{model}/{variant}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn enabled_telemetry_profiles_served_forwards() {
        let mut backend = ZooBackend::new(tiny("bert"), None).unwrap();
        let tele = backend.enable_telemetry();
        let mut m = backend.load().unwrap();
        let dims = m.dims();
        let packed = vec![0.1f32; dims.batch * dims.per_request_len()];
        m.run("model_tw", &packed).unwrap();
        let prof = tele.variant("model_tw").expect("variant registered at load");
        assert_eq!(prof.forwards(), 1);
        assert!(prof.nodes.iter().any(|n| n.calls() > 0), "GEMM nodes attributed");
        // sibling variants are registered but untouched until they serve
        assert_eq!(tele.variant("model_dense").unwrap().forwards(), 0);
    }

    #[test]
    fn decode_capable_models_advertise_caps_and_step() {
        for model in ["nmt", "decoder"] {
            let spec = tiny(model);
            let backend = ZooBackend::new(spec, None).unwrap();
            assert!(backend.decode_set().is_some(), "{model} compiles a decode set");
            let mut m = backend.load().unwrap();
            let caps = m.decode_caps().expect("decode caps advertised");
            assert_eq!(caps.slots, m.dims().batch, "{model}");
            let slot = m.decode_free_slot().expect("a free slot at load");
            let prompt: Vec<f32> =
                (0..2 * caps.d_in).map(|i| ((i % 5) as f32 - 2.0) * 0.2).collect();
            m.decode_begin(slot, &prompt).unwrap();
            assert_eq!(m.decode_active(), 1);
            for step in 0..3 {
                let outs = m.decode_step("model_tw").unwrap();
                assert_eq!(outs.len(), 1, "{model}");
                assert_eq!(outs[0].step, step);
                assert!(outs[0].logits.iter().all(|v| v.is_finite()), "{model}");
            }
            m.decode_end(slot).unwrap();
            assert_eq!(m.decode_active(), 0);
        }
        // one-shot-only models advertise nothing and refuse decode calls
        let backend = ZooBackend::new(tiny("bert"), None).unwrap();
        let mut m = backend.load().unwrap();
        assert!(m.decode_caps().is_none());
        assert!(m.decode_begin(0, &[0.0; 16]).is_err());
    }

    #[test]
    fn conv_models_serve_batch_one() {
        let backend = ZooBackend::new(tiny("vgg"), None).unwrap();
        assert_eq!(backend.dims().batch, 1);
        assert_eq!(backend.dims().per_request_len(), 3 * 32 * 32);
    }
}
