//! PJRT backend: adapts the AOT-artifact [`crate::runtime::Engine`] to the
//! [`Backend`] trait.  The engine is created inside [`Backend::load`] —
//! i.e. inside each worker thread — because PJRT handles wrap `Rc` and are
//! not `Send`.  Without the `pjrt` feature the engine is the std-only stub
//! whose `load_only` always fails, so a server started on this backend
//! degrades at startup exactly as the pre-`exec` code did.

use std::path::{Path, PathBuf};

use super::{Backend, ModelDims, PreparedModel};
use crate::ensure;
use crate::error::Result;
use crate::runtime::Engine;

/// Artifact directory + the executable names to load per worker.
pub struct PjrtBackend {
    dir: PathBuf,
    variants: Vec<String>,
}

impl PjrtBackend {
    pub fn new(artifact_dir: &Path, variants: &[String]) -> PjrtBackend {
        PjrtBackend { dir: artifact_dir.to_path_buf(), variants: variants.to_vec() }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn load(&self) -> Result<Box<dyn PreparedModel>> {
        ensure!(!self.variants.is_empty(), "pjrt backend needs at least one variant to load");
        let refs: Vec<&str> = self.variants.iter().map(String::as_str).collect();
        let engine = Engine::load_only(&self.dir, &refs)?;
        let m = engine.model(&self.variants[0])?;
        ensure!(
            m.output_shape.len() >= 2,
            "executable {} output shape {:?} is not (batch, classes)",
            self.variants[0],
            m.output_shape
        );
        let dims = ModelDims {
            batch: m.output_shape[0],
            n_classes: m.output_shape[1],
            seq: engine.meta.seq,
            d_model: engine.meta.d_model,
        };
        Ok(Box::new(PjrtModel { engine, dims, variants: self.variants.clone() }))
    }
}

/// PJRT shapes are ahead-of-time static, so this model keeps **padded
/// semantics** behind the dynamic-batch API: it deliberately inherits the
/// default [`PreparedModel::run_batch`], which treats `m_eff` as advisory
/// — it zero-pads the real-request prefix back to the artifact's fixed
/// batch, executes the full batch, and trims the logits.  Numerically
/// identical to the pre-dynamic coordinator; the compute saving of
/// variable M is a native/graph-backend property.
struct PjrtModel {
    engine: Engine,
    dims: ModelDims,
    variants: Vec<String>,
}

impl PreparedModel for PjrtModel {
    fn dims(&self) -> ModelDims {
        self.dims
    }

    fn variants(&self) -> Vec<String> {
        self.variants.clone()
    }

    fn run(&mut self, variant: &str, packed: &[f32]) -> Result<Vec<f32>> {
        self.engine.run_named(variant, packed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Without the `pjrt` feature (or without artifacts) the backend must
    /// fail cleanly at load — the stub degradation path the serving tests
    /// rely on.
    #[test]
    fn missing_artifacts_fail_at_load() {
        let backend =
            PjrtBackend::new(Path::new("/no/such/artifacts"), &["model_dense".to_string()]);
        assert_eq!(backend.name(), "pjrt");
        assert!(backend.load().is_err());
    }
}
