//! Minimal JSON parser + writer.
//!
//! The offline registry has no `serde`/`serde_json`, so the runtime's
//! artifact index (`bundle.json`, `meta.json`) and the figure-harness
//! output files are handled by this self-contained implementation.  It
//! supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null) — enough for machine-generated documents; it does
//! not aim to be a streaming or zero-copy parser.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// `obj["a"]["b"][2]`-style access for tests/tools.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(v) => v.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    // -- writer --------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) if x.is_nan() || x.is_infinite() => out.push_str("null"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building documents.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn nums(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..]).map_err(|e| e.to_string())?;
                    let ch = text.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|e| e.to_string())?;
        text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {text:?}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_document() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": true}, "e": null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.at(&["a", "1"]).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.at(&["b", "c"]).unwrap().as_str(), Some("hi\nthere"));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123abc").is_err());
        assert!(Json::parse("{} {}").is_err());
    }

    #[test]
    fn parses_real_bundle_index_shape() {
        let text = r#"{"blob": "bundle.bin", "tensors": [
            {"name": "model_tw/layer0/wqkv/b_cond", "dtype": "f32",
             "shape": [4, 64, 16], "offset": 0, "nbytes": 16384}]}"#;
        let v = Json::parse(text).unwrap();
        let t = &v.get("tensors").unwrap().as_arr().unwrap()[0];
        assert_eq!(t.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(t.get("shape").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(t.get("nbytes").unwrap().as_usize(), Some(16384));
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn writer_escapes_control_chars() {
        let v = Json::Str("a\"b\\c\nd".into());
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }
}
