//! Minimal error handling: the subset of `anyhow`'s API this crate uses
//! (`Result`, `anyhow!`, `bail!`, `ensure!`, `Context`), implemented over a
//! plain message-carrying error.  The offline crate registry has no
//! `anyhow`, so — like `json` and `util` — the facility lives in-tree.

use std::fmt;

/// A boxed-message error: a context chain flattened into one string.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    /// Prepend a context layer (`"{context}: {cause}"`).
    pub fn context(self, context: impl fmt::Display) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error::msg(msg)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::sync::mpsc::RecvError> for Error {
    fn from(e: std::sync::mpsc::RecvError) -> Error {
        Error::msg(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style adapters for results and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (the `anyhow!` shape).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

/// Early-return with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 7)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "inner 7");
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.with_context(|| "reading meta.json").unwrap_err();
        assert!(e.to_string().starts_with("reading meta.json: "));
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn question_mark_converts_io() {
        fn inner() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(30).is_err());
    }
}
