//! Accuracy models: `proxy` (a genuinely trained + pruned + fine-tuned MLP
//! validating the paper's accuracy *ordering* mechanism) and `surrogate`
//! (calibrated per-model curves reproducing the paper's *magnitudes*).
//! Every figure harness reports which source produced its accuracy axis.

pub mod proxy;
pub mod surrogate;

pub use proxy::{prune_finetune_sweep, Mlp, SweepPoint, Task};
pub use surrogate::{accuracy, max_sparsity_within_tolerance, ModelFamily};
