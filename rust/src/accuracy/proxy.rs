//! Trainable proxy model: a 2-layer MLP classifier trained with SGD on a
//! synthetic Gaussian-cluster task, run through the *real* multi-stage
//! prune→fine-tune loop (Algorithm 1) for every sparsity pattern.
//!
//! The paper fine-tunes BERT/VGG/ResNet/NMT on their datasets — hardware
//! and data we don't have (DESIGN.md §1).  The proxy preserves the
//! *mechanism* that produces the paper's accuracy ordering: pattern
//! constraint tightness determines how much importance mass pruning can
//! retain, and fine-tuning recovers what the constraint allows.  Expected
//! ordering (paper Fig. 6c/8): EW >= TEW >= TVW >= TW >= VW >= BW, with a
//! collapse past ~75% sparsity for the structured patterns.

use crate::gemm::matmul;
use crate::sparse::{Mask, Pattern};
use crate::tensor::Matrix;
use crate::util::Rng;

/// Synthetic classification task: `classes` Gaussian clusters in
/// `dim`-dimensional space with within-cluster correlated structure (so
/// weights have genuinely uneven importance — what TW exploits).
pub struct Task {
    pub dim: usize,
    pub classes: usize,
    pub train_x: Matrix,
    pub train_y: Vec<usize>,
    pub test_x: Matrix,
    pub test_y: Vec<usize>,
}

impl Task {
    pub fn synth(dim: usize, classes: usize, n_train: usize, n_test: usize, seed: u64) -> Task {
        let mut rng = Rng::new(seed);
        // cluster means; a small subset of dimensions is informative and
        // the separation is modest, so the task does not saturate — pruning
        // damage must be visible.  The skew also gives the weight matrix an
        // uneven importance distribution (what TW exploits).
        let mut means = Matrix::zeros(classes, dim);
        let informative = (dim / 4).max(4);
        for c in 0..classes {
            for d in 0..informative {
                *means.at_mut(c, d) = rng.normal_f32() * 0.9;
            }
        }
        let mut gen = |n: usize| {
            let mut x = Matrix::zeros(n, dim);
            let mut y = Vec::with_capacity(n);
            for i in 0..n {
                let c = rng.below(classes);
                y.push(c);
                for d in 0..dim {
                    *x.at_mut(i, d) = means.at(c, d) + rng.normal_f32();
                }
            }
            (x, y)
        };
        let (train_x, train_y) = gen(n_train);
        let (test_x, test_y) = gen(n_test);
        Task { dim, classes, train_x, train_y, test_x, test_y }
    }
}

/// 2-layer MLP: x -> relu(x W1) W2 -> softmax.
#[derive(Clone)]
pub struct Mlp {
    pub w1: Matrix,
    pub w2: Matrix,
}

impl Mlp {
    pub fn init(dim: usize, hidden: usize, classes: usize, seed: u64) -> Mlp {
        let mut rng = Rng::new(seed);
        Mlp { w1: Matrix::randn(dim, hidden, &mut rng), w2: Matrix::randn(hidden, classes, &mut rng) }
    }

    fn forward(&self, x: &Matrix) -> (Matrix, Matrix) {
        let mut h = matmul(x, &self.w1);
        for v in &mut h.data {
            *v = v.max(0.0);
        }
        let logits = matmul(&h, &self.w2);
        (h, logits)
    }

    pub fn accuracy(&self, x: &Matrix, y: &[usize]) -> f64 {
        let (_, logits) = self.forward(x);
        argmax_accuracy(&logits, y)
    }

    /// Test accuracy with the forward run through the int8 serving path:
    /// both weight matrices per-channel quantized ([`QuantMatrix`]), the
    /// activations dynamically quantized per GEMM — the exact arithmetic
    /// `serve --precision int8` dispatches.  The guardrail tests compare
    /// this against [`Mlp::accuracy`] to bound quantization damage on the
    /// surrogate score.
    pub fn accuracy_int8(&self, x: &Matrix, y: &[usize]) -> f64 {
        use crate::gemm::{int8_matmul_tiled_into, GemmScratch, TileConfig};
        use crate::quant::QuantMatrix;
        let q1 = QuantMatrix::quantize(&self.w1);
        let q2 = QuantMatrix::quantize(&self.w2);
        let cfg = TileConfig::dense_default();
        let mut scratch = GemmScratch::new();
        let mut h = Matrix::zeros(x.rows, self.w1.cols);
        int8_matmul_tiled_into(x, &q1, None, &mut h, &cfg, &mut scratch);
        for v in &mut h.data {
            *v = v.max(0.0);
        }
        let mut logits = Matrix::zeros(x.rows, self.w2.cols);
        int8_matmul_tiled_into(&h, &q2, None, &mut logits, &cfg, &mut scratch);
        argmax_accuracy(&logits, y)
    }

    /// One epoch of minibatch SGD with optional masks (masked-out weights
    /// receive no update and stay zero — pruning-aware fine-tuning).
    pub fn sgd_epoch(
        &mut self,
        x: &Matrix,
        y: &[usize],
        lr: f32,
        batch: usize,
        masks: Option<(&Mask, &Mask)>,
        rng: &mut Rng,
    ) {
        let n = x.rows;
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for chunk in order.chunks(batch) {
            let bs = chunk.len();
            let mut xb = Matrix::zeros(bs, x.cols);
            for (bi, &i) in chunk.iter().enumerate() {
                xb.row_mut(bi).copy_from_slice(x.row(i));
            }
            let (h, logits) = self.forward(&xb);
            // softmax CE gradient on logits
            let mut dl = Matrix::zeros(bs, self.w2.cols);
            for bi in 0..bs {
                let row = logits.row(bi);
                let mx = row.iter().fold(f32::MIN, |a, &b| a.max(b));
                let exps: Vec<f32> = row.iter().map(|&v| (v - mx).exp()).collect();
                let z: f32 = exps.iter().sum();
                for c in 0..self.w2.cols {
                    let p = exps[c] / z;
                    *dl.at_mut(bi, c) = (p - ((y[chunk[bi]] == c) as u8 as f32)) / bs as f32;
                }
            }
            // grads
            let dw2 = matmul(&h.transpose(), &dl);
            let mut dh = matmul(&dl, &self.w2.transpose());
            for (dv, hv) in dh.data.iter_mut().zip(&h.data) {
                if *hv <= 0.0 {
                    *dv = 0.0;
                }
            }
            let dw1 = matmul(&xb.transpose(), &dh);
            // update
            for (w, d) in self.w1.data.iter_mut().zip(&dw1.data) {
                *w -= lr * d;
            }
            for (w, d) in self.w2.data.iter_mut().zip(&dw2.data) {
                *w -= lr * d;
            }
            if let Some((m1, m2)) = masks {
                for (w, k) in self.w1.data.iter_mut().zip(&m1.keep) {
                    if !*k {
                        *w = 0.0;
                    }
                }
                for (w, k) in self.w2.data.iter_mut().zip(&m2.keep) {
                    if !*k {
                        *w = 0.0;
                    }
                }
            }
        }
    }
}

fn argmax_accuracy(logits: &Matrix, y: &[usize]) -> f64 {
    let mut correct = 0usize;
    for i in 0..logits.rows {
        let row = logits.row(i);
        let pred =
            row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        correct += (pred == y[i]) as usize;
    }
    correct as f64 / logits.rows as f64
}

/// Result of one pattern's prune→fine-tune sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub sparsity: f64,
    pub accuracy: f64,
}

/// Train a dense MLP, then multi-stage prune the hidden weight matrix W1
/// with `pattern` (W2 stays dense — it is tiny), fine-tuning between
/// stages; report accuracy at each target sparsity.
pub fn prune_finetune_sweep(
    task: &Task,
    pattern: Pattern,
    sparsities: &[f64],
    hidden: usize,
    seed: u64,
) -> Vec<SweepPoint> {
    let mut rng = Rng::new(seed ^ 0xABCD);
    let mut model = Mlp::init(task.dim, hidden, task.classes, seed);
    for _ in 0..30 {
        model.sgd_epoch(&task.train_x, &task.train_y, 0.05, 32, None, &mut rng);
    }
    let mut out = Vec::new();
    let full2 = Mask::all(model.w2.rows, model.w2.cols);
    for &s in sparsities {
        // TVW cannot express < 50%; ramp through TW (as the pruner does)
        let eff = match pattern {
            Pattern::Tvw { g, .. } if s < 0.5 => Pattern::Tw { g },
            p => p,
        };
        let mask = eff.prune(&model.w1, s);
        model.w1 = mask.apply(&model.w1);
        for _ in 0..10 {
            model.sgd_epoch(&task.train_x, &task.train_y, 0.05, 32, Some((&mask, &full2)), &mut rng);
        }
        out.push(SweepPoint { sparsity: s, accuracy: model.accuracy(&task.test_x, &task.test_y) });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_task() -> Task {
        Task::synth(32, 4, 800, 400, 7)
    }

    #[test]
    fn dense_model_learns() {
        let task = small_task();
        let mut rng = Rng::new(1);
        let mut m = Mlp::init(task.dim, 64, task.classes, 2);
        let before = m.accuracy(&task.test_x, &task.test_y);
        for _ in 0..20 {
            m.sgd_epoch(&task.train_x, &task.train_y, 0.05, 32, None, &mut rng);
        }
        let after = m.accuracy(&task.test_x, &task.test_y);
        assert!(after > 0.8, "dense accuracy {after}");
        assert!(after > before);
    }

    #[test]
    fn mild_pruning_retains_accuracy() {
        let task = small_task();
        let pts = prune_finetune_sweep(&task, Pattern::Ew, &[0.5], 64, 3);
        assert!(pts[0].accuracy > 0.75, "{pts:?}");
    }

    #[test]
    fn int8_quantization_guardrail_on_surrogate_score() {
        // the PR 9 accuracy contract: serving the pruned + fine-tuned
        // surrogate at int8 (per-channel weights, dynamic activations)
        // moves its test score by at most 0.5% absolute vs the f32 path
        let task = Task::synth(32, 4, 1200, 1000, 13);
        let mut rng = Rng::new(17);
        let mut m = Mlp::init(task.dim, 64, task.classes, 19);
        for _ in 0..30 {
            m.sgd_epoch(&task.train_x, &task.train_y, 0.05, 32, None, &mut rng);
        }
        let mask = Pattern::Tw { g: 8 }.prune(&m.w1, 0.75);
        m.w1 = mask.apply(&m.w1);
        let full2 = Mask::all(m.w2.rows, m.w2.cols);
        for _ in 0..10 {
            m.sgd_epoch(&task.train_x, &task.train_y, 0.05, 32, Some((&mask, &full2)), &mut rng);
        }
        let f32_acc = m.accuracy(&task.test_x, &task.test_y);
        let int8_acc = m.accuracy_int8(&task.test_x, &task.test_y);
        assert!(f32_acc > 0.7, "pruned surrogate should still classify: {f32_acc}");
        assert!(
            (f32_acc - int8_acc).abs() <= 0.005,
            "int8 surrogate score {int8_acc} drifted more than 0.5% from f32 {f32_acc}"
        );
    }

    #[test]
    fn masked_sgd_keeps_zeros() {
        let task = small_task();
        let mut rng = Rng::new(4);
        let mut m = Mlp::init(task.dim, 32, task.classes, 5);
        let mask = Pattern::Ew.prune(&m.w1, 0.7);
        m.w1 = mask.apply(&m.w1);
        let full2 = Mask::all(m.w2.rows, m.w2.cols);
        m.sgd_epoch(&task.train_x, &task.train_y, 0.05, 32, Some((&mask, &full2)), &mut rng);
        for (w, k) in m.w1.data.iter().zip(&mask.keep) {
            if !*k {
                assert_eq!(*w, 0.0);
            }
        }
    }

    #[test]
    #[ignore = "slow ordering validation; run explicitly"]
    fn accuracy_ordering_matches_paper() {
        let task = Task::synth(64, 8, 2000, 800, 11);
        let sp = [0.25, 0.5, 0.75];
        let ew = prune_finetune_sweep(&task, Pattern::Ew, &sp, 128, 1);
        let tw = prune_finetune_sweep(&task, Pattern::Tw { g: 16 }, &sp, 128, 1);
        let bw = prune_finetune_sweep(&task, Pattern::Bw { g: 16 }, &sp, 128, 1);
        // at 75%: EW >= TW >= BW (allow small noise)
        assert!(ew[2].accuracy + 0.02 >= tw[2].accuracy, "EW {} TW {}", ew[2].accuracy, tw[2].accuracy);
        assert!(tw[2].accuracy + 0.02 >= bw[2].accuracy, "TW {} BW {}", tw[2].accuracy, bw[2].accuracy);
    }
}
