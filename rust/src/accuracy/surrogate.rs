//! Calibrated surrogate accuracy model: parametric accuracy-vs-sparsity
//! curves per (model family, pattern), fitted to the paper's reported
//! numbers so the figure harnesses can emit curves on the paper's absolute
//! scale (Fig. 6c, 7a, 8, 10, 11).
//!
//! This is explicitly a *surrogate* (DESIGN.md §1): the real fine-tuning
//! mechanism is validated by `accuracy::proxy`; this module reproduces
//! magnitudes.  Functional form:
//!
//!   acc(s) = base − c_pattern · sens_model · drop(s)
//!   drop(s) = a·s² + b·max(0, s − s_knee)^2.5
//!
//! with the knee at 75% sparsity — the paper's "rapid accuracy drop when
//! sparsity is over 75%" (§VI-C).  Pattern constraint factors follow the
//! paper's observed ordering: EW < TVW-16 < TVW-4 < VW-16 ≈ TEW < TW <
//! VW-4 < BW-16 < BW-64.

use crate::sparse::Pattern;

/// Model families with paper-reported baseline metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelFamily {
    Vgg16,       // top-5 ImageNet
    Resnet18,    // top-5
    Resnet50,    // top-5
    Nmt,         // BLEU, IWSLT En-Vi
    BertMnli,    // accuracy
    BertSquad,   // F1
}

impl ModelFamily {
    pub fn label(&self) -> &'static str {
        match self {
            ModelFamily::Vgg16 => "VGG16",
            ModelFamily::Resnet18 => "ResNet-18",
            ModelFamily::Resnet50 => "ResNet-50",
            ModelFamily::Nmt => "NMT",
            ModelFamily::BertMnli => "BERT-MNLI",
            ModelFamily::BertSquad => "BERT-SQuAD",
        }
    }

    /// Dense baseline metric (reference accuracies of the pre-trained
    /// models the paper starts from).
    pub fn baseline(&self) -> f64 {
        match self {
            ModelFamily::Vgg16 => 90.4,
            ModelFamily::Resnet18 => 89.1,
            ModelFamily::Resnet50 => 92.9,
            ModelFamily::Nmt => 25.5,
            ModelFamily::BertMnli => 84.3,
            ModelFamily::BertSquad => 88.5,
        }
    }

    /// Sensitivity multiplier (how steeply this model loses accuracy):
    /// SQuAD is "sensitive to sparsity" (§VI-D); NMT's BLEU scale is
    /// smaller so absolute drops are smaller.
    fn sensitivity(&self) -> f64 {
        match self {
            ModelFamily::Vgg16 => 0.8,
            ModelFamily::Resnet18 => 1.0,
            ModelFamily::Resnet50 => 1.0,
            ModelFamily::Nmt => 0.45,
            ModelFamily::BertMnli => 1.0,
            ModelFamily::BertSquad => 1.5,
        }
    }

    /// Iso-accuracy tolerance used by the Fig. 10/11 "same accuracy drop"
    /// comparison (<2% accuracy / <1 BLEU).
    pub fn tolerance(&self) -> f64 {
        match self {
            ModelFamily::Nmt => 1.0,
            _ => 2.0,
        }
    }
}

/// Pattern constraint-tightness factor (fitted against the paper's Fig.
/// 6c/7a/8 anchors; see module doc):
///   - TW-128 sits ~1.6% below EW at 75% on BERT-MNLI => factor 4.2
///     against drop_shape(0.75) ~= 0.51;
///   - BW-64 drops >5% at 75% => factor ~18;
///   - TEW delta=5% catches EW, delta=10% surpasses it;
///   - TVW-16 > TVW-4 > TW; VW-16 slightly better than TW below 75%.
fn pattern_factor(p: &Pattern) -> f64 {
    match p {
        Pattern::Ew => 1.0,
        Pattern::Tew { delta_pct, .. } => {
            let d = *delta_pct as f64 / 100.0;
            (4.2 - 64.0 * d).max(0.9)
        }
        Pattern::Tvw { m: 16, .. } => 1.8,
        Pattern::Tvw { .. } => 2.5,
        Pattern::Vw { m: 16 } => 3.0,
        Pattern::Vw { .. } => 5.0,
        Pattern::Tw { g } => (4.2 + 0.6 * (*g as f64 / 128.0).log2()).max(3.0),
        Pattern::Bw { g } => 6.0 * (*g as f64 / 16.0).powf(0.8),
    }
}

/// Accuracy drop shape: gentle quadratic below the 75% knee, steep beyond
/// (the §VI-C collapse).
fn drop_shape(s: f64) -> f64 {
    let knee = 0.75;
    0.9 * s * s + 120.0 * (s - knee).max(0.0).powf(2.5)
}

/// Surrogate accuracy of `family` pruned with `pattern` at `sparsity`.
///
/// VW has a *fixed* sparsity (50% for 2:4, 75% for 4:16): querying other
/// sparsities returns the fixed point's accuracy, matching how the paper
/// plots VW as a single point.
pub fn accuracy(family: ModelFamily, pattern: &Pattern, sparsity: f64) -> f64 {
    let s = match pattern {
        Pattern::Vw { m: 4 } => 0.5,
        Pattern::Vw { m: 16 } => 0.75,
        _ => sparsity,
    };
    let base = family.baseline();
    let drop = pattern_factor(pattern) * family.sensitivity() * drop_shape(s);
    (base - drop).max(0.0)
}

/// Highest sparsity at which `pattern` keeps `family` within its
/// iso-accuracy tolerance (the Fig. 10/11 operating point), searched on a
/// 1% grid over the pattern's feasible range.
pub fn max_sparsity_within_tolerance(family: ModelFamily, pattern: &Pattern) -> f64 {
    let lo = match pattern {
        Pattern::Tvw { .. } => 0.50,
        _ => 0.0,
    };
    let tol = family.tolerance();
    let base = family.baseline();
    let mut best = lo;
    let mut s = lo;
    while s <= 0.99 {
        if base - accuracy(family, pattern, s) <= tol {
            best = s;
        }
        s += 0.01;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_at_zero_sparsity() {
        for f in [ModelFamily::Vgg16, ModelFamily::BertMnli, ModelFamily::Nmt] {
            assert!((accuracy(f, &Pattern::Ew, 0.0) - f.baseline()).abs() < 1e-9);
        }
    }

    #[test]
    fn paper_anchor_tw128_bert_75pct() {
        // Fig. 6c: TW-128 loses ~1.6% vs EW at 75% on BERT-MNLI
        let ew = accuracy(ModelFamily::BertMnli, &Pattern::Ew, 0.75);
        let tw = accuracy(ModelFamily::BertMnli, &Pattern::Tw { g: 128 }, 0.75);
        let gap = ew - tw;
        assert!((gap - 1.6).abs() < 1.0, "gap {gap}");
    }

    #[test]
    fn paper_anchor_bw64_drastic() {
        // Fig. 6c: BW-64 drops >5% at 75%
        let drop = ModelFamily::BertMnli.baseline()
            - accuracy(ModelFamily::BertMnli, &Pattern::Bw { g: 64 }, 0.75);
        assert!(drop > 5.0, "BW-64 drop {drop}");
    }

    #[test]
    fn ordering_at_85pct() {
        let f = ModelFamily::BertMnli;
        let at = |p: &Pattern| accuracy(f, p, 0.85);
        let ew = at(&Pattern::Ew);
        let tvw16 = at(&Pattern::Tvw { g: 128, m: 16 });
        let tvw4 = at(&Pattern::Tvw { g: 128, m: 4 });
        let tw = at(&Pattern::Tw { g: 128 });
        let bw = at(&Pattern::Bw { g: 16 });
        assert!(ew > tvw16 && tvw16 > tvw4 && tvw4 > tw && tw > bw,
                "{ew} {tvw16} {tvw4} {tw} {bw}");
    }

    #[test]
    fn tew_delta_crosses_ew() {
        // Fig. 7a: TEW with delta=10% surpasses EW
        let f = ModelFamily::BertMnli;
        let ew = accuracy(f, &Pattern::Ew, 0.8);
        let tew10 = accuracy(f, &Pattern::Tew { g: 128, delta_pct: 10 }, 0.8);
        let tew1 = accuracy(f, &Pattern::Tew { g: 128, delta_pct: 1 }, 0.8);
        assert!(tew10 >= ew - 0.1, "TEW-10 {tew10} vs EW {ew}");
        assert!(tew1 < ew);
    }

    #[test]
    fn collapse_past_knee() {
        let f = ModelFamily::BertMnli;
        let d75 = f.baseline() - accuracy(f, &Pattern::Tw { g: 128 }, 0.75);
        let d90 = f.baseline() - accuracy(f, &Pattern::Tw { g: 128 }, 0.90);
        assert!(d90 > 3.0 * d75, "collapse: {d75} -> {d90}");
    }

    #[test]
    fn squad_more_sensitive() {
        let p = Pattern::Tw { g: 128 };
        let mnli_drop = ModelFamily::BertMnli.baseline() - accuracy(ModelFamily::BertMnli, &p, 0.8);
        let squad_drop =
            ModelFamily::BertSquad.baseline() - accuracy(ModelFamily::BertSquad, &p, 0.8);
        assert!(squad_drop > mnli_drop);
    }

    #[test]
    fn iso_accuracy_operating_points_ordered() {
        let f = ModelFamily::BertMnli;
        let s_ew = max_sparsity_within_tolerance(f, &Pattern::Ew);
        let s_tw = max_sparsity_within_tolerance(f, &Pattern::Tw { g: 128 });
        let s_bw = max_sparsity_within_tolerance(f, &Pattern::Bw { g: 16 });
        assert!(s_ew >= s_tw && s_tw >= s_bw, "{s_ew} {s_tw} {s_bw}");
        assert!(s_tw > 0.5, "TW should sustain >50% at iso-accuracy: {s_tw}");
    }
}
