//! A100-class GPU constants and execution pipes.
//!
//! Peak numbers from the NVIDIA A100 whitepaper [9]: 108 SMs, 19.5 TFLOPS
//! FP32 (CUDA cores), 312 TFLOPS FP16 (tensor cores), 624 TFLOPS FP16 on
//! the sparse tensor core (2:4), 624/1248 TOPS INT8 dense/sparse, 1555
//! GB/s HBM2e.

/// Static hardware description used by the latency model.
#[derive(Clone, Debug)]
pub struct GpuSpecs {
    pub name: &'static str,
    pub sms: usize,
    /// HBM bandwidth, bytes/second.
    pub hbm_bytes_per_sec: f64,
    /// FP32 CUDA-core throughput, FLOP/s.
    pub cuda_fp32_flops: f64,
    /// FP16 dense tensor-core throughput, FLOP/s.
    pub tc_fp16_flops: f64,
    /// FP16 sparse tensor-core throughput on 2:4 *kept* operations, FLOP/s.
    /// (The STC doubles per-cycle MACs; counting only the kept half of the
    /// operands, its effective rate on kept FLOPs equals the dense rate —
    /// the 2x shows up because the kept FLOPs are half the dense FLOPs.)
    pub stc_fp16_flops: f64,
    /// INT8 tensor-core throughput, OP/s.
    pub tc_int8_ops: f64,
    pub stc_int8_ops: f64,
    /// Kernel launch overhead, seconds.
    pub launch_overhead: f64,
    /// Fixed per-threadblock-tile overhead, seconds (scheduling, smem
    /// staging latency, epilogue).  This term is what makes small tiles
    /// (BW-16) inefficient.
    pub tile_overhead: f64,
    /// Transaction-inflation factor for uncoalesced global accesses
    /// (32B granules out of 128B lines).
    pub uncoalesced_factor: f64,
}

/// Execution pipe: which functional units + datatype a kernel runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pipe {
    /// FP32 on CUDA cores.
    CudaFp32,
    /// FP16 on dense tensor cores.
    TensorFp16,
    /// FP16 2:4 on sparse tensor cores (rate applies to *kept* FLOPs).
    SparseTensorFp16,
    /// INT8 on dense tensor cores.
    TensorInt8,
    /// INT8 2:4 on sparse tensor cores.
    SparseTensorInt8,
}

impl Pipe {
    /// Peak rate in (kept-)FLOP/s on `specs`.
    pub fn rate(&self, specs: &GpuSpecs) -> f64 {
        match self {
            Pipe::CudaFp32 => specs.cuda_fp32_flops,
            Pipe::TensorFp16 => specs.tc_fp16_flops,
            Pipe::SparseTensorFp16 => specs.stc_fp16_flops,
            Pipe::TensorInt8 => specs.tc_int8_ops,
            Pipe::SparseTensorInt8 => specs.stc_int8_ops,
        }
    }

    /// Bytes per element of the operand datatype.
    pub fn elem_bytes(&self) -> f64 {
        match self {
            Pipe::CudaFp32 => 4.0,
            Pipe::TensorFp16 | Pipe::SparseTensorFp16 => 2.0,
            Pipe::TensorInt8 | Pipe::SparseTensorInt8 => 1.0,
        }
    }
}

/// The Tesla A100 of the paper's testbed.
pub fn a100() -> GpuSpecs {
    GpuSpecs {
        name: "A100",
        sms: 108,
        hbm_bytes_per_sec: 1.555e12,
        cuda_fp32_flops: 19.5e12,
        tc_fp16_flops: 312e12,
        stc_fp16_flops: 312e12, // on kept FLOPs; see field doc
        tc_int8_ops: 624e12,
        stc_int8_ops: 624e12,
        launch_overhead: 4e-6,
        tile_overhead: 1.2e-6,
        uncoalesced_factor: 4.0,
    }
}

/// Calibrated per-pattern efficiency factors (fraction of pipe peak a
/// well-tuned kernel of that family reaches on large compute-bound
/// shapes).  Each value is derived once from an anchor the paper states
/// explicitly, then *frozen* — EXPERIMENTS.md records anchor vs model:
///   - dense TC ~ 9.7x over dense CUDA on 4096^3 (Fig. 6b)
///     => dense_eff_tc / dense_eff_cuda = 9.7 / 16;
///   - VW-4 = 1.67x over dense TC on 4096^3 (Fig. 6a)
///     => stc_eff = dense_eff_tc * 1.67 / 2;
///   - TW-128 crossover vs dense at ~10% sparsity on TC, ~5% on CUDA
///     => tw_eff = dense_eff * (1 - crossover);
///   - EW (cuSparse) crossover vs dense CUDA at ~95% sparsity
///     => ew_eff = dense_eff_cuda * 0.05;
///   - BW-32 / BW-16 crossovers at 40% / 70% on TC
///     => bw_eff(g) ~ dense_eff_tc * g / 53 (linear small-tile MMA loss);
///   - Int8-dense 1.62x, Int8-sparse 2.16x over FP16 dense TC (§VI-B).
#[derive(Clone, Debug)]
pub struct Calibration {
    pub dense_eff_tc: f64,
    pub dense_eff_cuda: f64,
    pub stc_eff: f64,
    pub tw_eff_tc: f64,
    pub tw_eff_cuda: f64,
    /// BW efficiency per unit of block size g (clamped to dense_eff_tc).
    pub bw_eff_per_g: f64,
    pub ew_eff: f64,
    pub int8_eff: f64,
    pub int8_sparse_eff: f64,
}

impl Calibration {
    pub fn bw_eff(&self, g: usize) -> f64 {
        (self.bw_eff_per_g * g as f64).min(self.dense_eff_tc)
    }
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration {
            dense_eff_tc: 0.60,
            dense_eff_cuda: 0.97,
            stc_eff: 0.50,       // 0.60 * 1.67 / 2
            tw_eff_tc: 0.54,     // 0.60 * (1 - 0.10)
            tw_eff_cuda: 0.92,   // 0.97 * (1 - 0.05)
            bw_eff_per_g: 0.01125, // g=16 -> 0.18, g=32 -> 0.36
            ew_eff: 0.0485,      // 0.97 * 0.05
            int8_eff: 0.49,      // 1.62x over FP16 dense TC
            int8_sparse_eff: 0.33, // 2.16x over FP16 dense TC
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_headline_ratio() {
        let s = a100();
        // 312 TFLOPS FP16 TC vs 19.5 TFLOPS FP32 CUDA = 16x raw
        assert!((s.tc_fp16_flops / s.cuda_fp32_flops - 16.0).abs() < 1e-9);
    }

    #[test]
    fn pipe_rates_monotone() {
        let s = a100();
        assert!(Pipe::TensorFp16.rate(&s) > Pipe::CudaFp32.rate(&s));
        assert!(Pipe::TensorInt8.rate(&s) > Pipe::TensorFp16.rate(&s));
    }

    #[test]
    fn elem_bytes() {
        assert_eq!(Pipe::CudaFp32.elem_bytes(), 4.0);
        assert_eq!(Pipe::TensorFp16.elem_bytes(), 2.0);
        assert_eq!(Pipe::TensorInt8.elem_bytes(), 1.0);
    }
}
