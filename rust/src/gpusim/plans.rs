//! Execution-plan builders: one per sparsity pattern / pipe combination.
//!
//! A plan builder turns (GEMM shape, sparsity, pattern parameters) into a
//! `Kernel` — a list of threadblock tiles with FLOPs and reuse-adjusted
//! HBM traffic — which `kernel::makespan` then schedules.  The builders
//! encode the paper's §V execution strategies, including the TW ablation
//! ladder (naive / transposed / batched streams / fused CTO).

use super::kernel::{concurrent_latency, Kernel, TileWork};
use super::specs::{Calibration, GpuSpecs, Pipe};
use crate::sparse::TwPlan;
use crate::util::ceil_div;

/// GEMM problem shape: C[M,N] = A[M,K] * B[K,N].
#[derive(Clone, Copy, Debug)]
pub struct GemmShape {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl GemmShape {
    pub fn new(m: usize, k: usize, n: usize) -> Self {
        Self { m, k, n }
    }
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.k as f64 * self.n as f64
    }
}

/// Wave-level input reuse: tiles executing concurrently share A rows and B
/// columns through L2, so effective per-tile input traffic divides by the
/// wave footprint along each grid dimension.
fn reuse(grid_m: usize, grid_n: usize, sms: usize) -> (f64, f64) {
    let active = (grid_m * grid_n).min(sms).max(1) as f64;
    let w = active.sqrt();
    // A-tile is reused by tiles along N; B-tile by tiles along M.
    (w.min(grid_n as f64).max(1.0), w.min(grid_m as f64).max(1.0))
}

/// Pick the output-tile M-extent: start from the requested Tm, but shrink
/// (to a 32-row floor) when the grid would otherwise leave SMs idle — the
/// occupancy-driven tile-size drop every tuned GEMM library (cuBLAS,
/// CUTLASS heuristics) makes for skinny problems.  Applied uniformly to
/// the dense baseline and all sparse plans so nobody gets a free
/// parallelism edge.
fn adaptive_tile_m(m: usize, num_tiles: usize, tile_m_max: usize, sms: usize) -> usize {
    let mut tile_m = tile_m_max.max(32);
    while tile_m > 32
        && ceil_div(m, tile_m) * num_tiles < 2 * sms
        && ceil_div(m, tile_m / 2) * num_tiles <= 4 * sms
    {
        tile_m /= 2;
    }
    tile_m.max(32)
}

/// Uniform output-tile kernel over an (M, N) grid with reduction `kred`
/// and per-tile extra input bytes `extra_in`.
#[allow(clippy::too_many_arguments)]
fn tiled_kernel(
    name: &str,
    pipe: Pipe,
    efficiency: f64,
    shape: GemmShape,
    tile_m: usize,
    tile_n: usize,
    kred: f64,
    b_bytes_per_elem: f64,
    extra_in_per_tile: f64,
    specs: &GpuSpecs,
) -> Kernel {
    let grid_n = ceil_div(shape.n, tile_n);
    let tile_m = adaptive_tile_m(shape.m, grid_n, tile_m, specs.sms);
    let grid_m = ceil_div(shape.m, tile_m);
    let (reuse_a, reuse_b) = reuse(grid_m, grid_n, specs.sms);
    let eb = pipe.elem_bytes();
    let flops = 2.0 * tile_m as f64 * tile_n as f64 * kred;
    let bytes_in = tile_m as f64 * kred * eb / reuse_a
        + kred * tile_n as f64 * b_bytes_per_elem / reuse_b
        + extra_in_per_tile;
    let bytes_out = tile_m as f64 * tile_n as f64 * eb;
    Kernel {
        name: name.to_string(),
        pipe,
        efficiency,
        serialize_mem: false,
        tiles: vec![TileWork { flops, bytes_in, bytes_out }; grid_m * grid_n],
    }
}

/// Dense GEMM on the chosen pipe (CUTLASS-style 128x128 tiles).
pub fn dense_plan(shape: GemmShape, pipe: Pipe, specs: &GpuSpecs, cal: &Calibration) -> Kernel {
    let eff = match pipe {
        Pipe::CudaFp32 => cal.dense_eff_cuda,
        Pipe::TensorFp16 => cal.dense_eff_tc,
        Pipe::TensorInt8 => cal.int8_eff,
        _ => cal.dense_eff_tc,
    };
    let eb = pipe.elem_bytes();
    tiled_kernel("dense", pipe, eff, shape, 128, 128, shape.k as f64, eb, 0.0, specs)
}

/// VW 2:4 on the sparse tensor core: kept FLOPs are half, B traffic is
/// half plus 2-bit metadata per dense element.
pub fn vw24_plan(shape: GemmShape, int8: bool, specs: &GpuSpecs, cal: &Calibration) -> Kernel {
    let (pipe, eff) = if int8 {
        (Pipe::SparseTensorInt8, cal.int8_sparse_eff)
    } else {
        (Pipe::SparseTensorFp16, cal.stc_eff)
    };
    let eb = pipe.elem_bytes();
    // B stored compressed: values (K/2) + metadata (2 bits per dense elem)
    let b_bytes = 0.5 * eb + 0.25 / 8.0 * 2.0;
    let mut k = tiled_kernel("vw24", pipe, eff, shape, 128, 128, shape.k as f64, b_bytes, 0.0, specs);
    // the STC executes only kept FLOPs: half the dense count
    for t in &mut k.tiles {
        t.flops *= 0.5;
    }
    k
}

/// BW block-sparse on the tensor core: grid of g x g output blocks, kept
/// fraction (1 - sparsity); small g costs MMA efficiency (calibrated) and
/// per-tile overhead (from specs).
pub fn bw_plan(shape: GemmShape, sparsity: f64, g: usize, specs: &GpuSpecs, cal: &Calibration) -> Kernel {
    let pipe = Pipe::TensorFp16;
    let eb = pipe.elem_bytes();
    let kred = shape.k as f64 * (1.0 - sparsity); // kept input blocks per block-column
    let grid_n = ceil_div(shape.n, g);
    let tile_m = adaptive_tile_m(shape.m, grid_n, 128, specs.sms);
    let grid_m = ceil_div(shape.m, tile_m);
    let (reuse_a, reuse_b) = reuse(grid_m, grid_n, specs.sms);
    let flops = 2.0 * tile_m as f64 * g as f64 * kred;
    let bytes_in = tile_m as f64 * kred * eb / reuse_a + kred * g as f64 * eb / reuse_b
        + (kred / g as f64) * 4.0; // block index per kept block
    let bytes_out = tile_m as f64 * g as f64 * eb;
    Kernel {
        name: format!("bw{g}"),
        pipe,
        efficiency: cal.bw_eff(g),
        serialize_mem: false,
        tiles: vec![TileWork { flops, bytes_in, bytes_out }; grid_m * grid_n],
    }
}

/// EW unstructured on CUDA cores via CSR SpMM (the cuSparse baseline):
/// nnz-proportional FLOPs at a heavily degraded effective rate, plus CSR
/// index traffic and uncoalesced output updates.
pub fn ew_plan(shape: GemmShape, sparsity: f64, specs: &GpuSpecs, cal: &Calibration) -> Kernel {
    let pipe = Pipe::CudaFp32;
    let nnz = (shape.k as f64 * shape.n as f64) * (1.0 - sparsity);
    // 2D grid: 32-row A bands x CSR column segments (cuSparse SpMM
    // parallelises over rows and nnz segments; fine 32-wide bands keep
    // skinny problems from leaving SMs idle, matching its CSR kernels).
    let band = 32usize;
    let grid_m = ceil_div(shape.m, band);
    let grid_n = ceil_div(shape.n, band);
    let (reuse_a, reuse_b) = reuse(grid_m, grid_n, specs.sms);
    let seg_nnz = nnz / grid_n as f64;
    let bm = band.min(shape.m) as f64;
    let tile_flops = 2.0 * bm * seg_nnz;
    let bytes_in = bm * shape.k as f64 * 4.0 / reuse_a     // A band (re-read per segment, L2-damped)
        + seg_nnz * (4.0 + 4.0) / reuse_b;                 // CSR vals + idx
    let bytes_out =
        bm * band.min(shape.n) as f64 * 4.0 * specs.uncoalesced_factor.min(2.0); // scattered C updates
    Kernel {
        name: "ew-csr".into(),
        pipe,
        efficiency: cal.ew_eff,
        serialize_mem: true, // CSR gathers cannot hide behind compute
        tiles: vec![TileWork { flops: tile_flops, bytes_in, bytes_out }; grid_m * grid_n],
    }
}

/// TW execution strategy — the §V / Fig. 4 optimization ladder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TwStrategy {
    /// Row-major tiles, uncoalesced gathers, one kernel launch per tile,
    /// one stream (the strawman).
    Naive,
    /// Transposed layout (coalesced) but still one launch per tile, serial.
    Transposed,
    /// Per-tile kernels on concurrent streams (the SC'20 implementation).
    BatchedStreams,
    /// Single fused kernel over all tiles with CTO offsets (this paper).
    FusedCto,
}

/// Per-tile descriptor extracted from a real or synthetic TW plan.
#[derive(Clone, Copy, Debug)]
pub struct TwTileDesc {
    /// Kept reduction length of this tile.
    pub kt: usize,
    /// Output width (<= G).
    pub width: usize,
}

/// Synthetic uniform tile set for a TW-pruned GEMM at a given sparsity:
/// column stage keeps (1-s_c)N columns, row stage keeps (1-s_r)K rows per
/// tile (the expectation of the real pruner's output).
pub fn tw_uniform_tiles(shape: GemmShape, sparsity: f64, g: usize) -> Vec<TwTileDesc> {
    let s_stage = 1.0 - (1.0 - sparsity).max(0.0).sqrt();
    let nk = ((1.0 - s_stage) * shape.n as f64).round() as usize;
    let kt = (((1.0 - s_stage) * shape.k as f64).round() as usize).max(1);
    let tiles = ceil_div(nk.max(1), g);
    (0..tiles)
        .map(|t| TwTileDesc { kt, width: g.min(nk - t * g) })
        .collect()
}

/// Tile descriptors from a real CTO plan (captures load imbalance).
pub fn tw_tiles_from_plan(plan: &TwPlan) -> Vec<TwTileDesc> {
    (0..plan.tiles)
        .map(|t| TwTileDesc {
            kt: plan.row_len[t] as usize,
            width: (0..plan.g)
                .take_while(|&j| (plan.col_idx[t * plan.g + j] as usize) < plan.n)
                .count(),
        })
        .collect()
}


/// Build the TW kernel(s) for a strategy and return its simulated latency.
///
/// The output tile is (Tm x G) with Tm co-scaled so Tm*G = 128*128 —
/// the paper's §VI-B trick keeping per-tile work constant across G.
pub fn tw_latency(
    shape: GemmShape,
    tiles: &[TwTileDesc],
    g: usize,
    pipe: Pipe,
    strategy: TwStrategy,
    specs: &GpuSpecs,
    cal: &Calibration,
) -> f64 {
    let eff = match pipe {
        Pipe::CudaFp32 => cal.tw_eff_cuda,
        _ => cal.tw_eff_tc,
    };
    let eb = pipe.elem_bytes();
    let tile_m = adaptive_tile_m(shape.m, tiles.len().max(1), (128 * 128 / g).max(32), specs.sms);
    let grid_m = ceil_div(shape.m, tile_m);
    let grid_n = tiles.len().max(1);
    let (reuse_a, reuse_b) = reuse(grid_m, grid_n, specs.sms);
    let uncoal = if strategy == TwStrategy::Naive { specs.uncoalesced_factor } else { 1.0 };

    let mk_tile = |d: &TwTileDesc| {
        let kt = d.kt as f64;
        let flops = 2.0 * tile_m as f64 * d.width as f64 * kt;
        let bytes_in = tile_m as f64 * kt * eb * uncoal / reuse_a  // gathered A
            + kt * d.width as f64 * eb / reuse_b                   // condensed B
            + kt * 4.0 + d.width as f64 * 4.0;                     // CTO tables
        let bytes_out = tile_m as f64 * d.width as f64 * eb * uncoal;
        TileWork { flops, bytes_in, bytes_out }
    };

    match strategy {
        TwStrategy::Naive | TwStrategy::Transposed => {
            // one kernel launch per condensed tile, serialized in one stream
            let mut total = 0.0;
            for d in tiles {
                let k = Kernel {
                    name: "tw-tile".into(),
                    pipe,
                    efficiency: eff,
                    serialize_mem: strategy == TwStrategy::Naive,
                    tiles: vec![mk_tile(d); grid_m],
                };
                total += k.latency(specs);
            }
            total
        }
        TwStrategy::BatchedStreams => {
            // per-tile kernels on concurrent streams
            let kernels: Vec<Kernel> = tiles
                .iter()
                .map(|d| Kernel {
                    name: "tw-stream".into(),
                    pipe,
                    efficiency: eff,
                    serialize_mem: false,
                    tiles: vec![mk_tile(d); grid_m],
                })
                .collect();
            concurrent_latency(&kernels, specs)
        }
        TwStrategy::FusedCto => {
            // single kernel over all (tile, m-band) pairs
            let mut all = Vec::with_capacity(tiles.len() * grid_m);
            for d in tiles {
                for _ in 0..grid_m {
                    all.push(mk_tile(d));
                }
            }
            Kernel { name: "tw-fused".into(), pipe, efficiency: eff, serialize_mem: false, tiles: all }
                .latency(specs)
        }
    }
}

/// TVW on the sparse tensor core: TW tile structure, with each tile's kept
/// FLOPs halved by 2:4 and B stored compressed.
pub fn tvw_latency(
    shape: GemmShape,
    tiles: &[TwTileDesc],
    g: usize,
    specs: &GpuSpecs,
    cal: &Calibration,
) -> f64 {
    let pipe = Pipe::SparseTensorFp16;
    let eb = pipe.elem_bytes();
    let tile_m = adaptive_tile_m(shape.m, tiles.len().max(1), (128 * 128 / g).max(32), specs.sms);
    let grid_m = ceil_div(shape.m, tile_m);
    let grid_n = tiles.len().max(1);
    let (reuse_a, reuse_b) = reuse(grid_m, grid_n, specs.sms);
    let mut all = Vec::with_capacity(tiles.len() * grid_m);
    for d in tiles {
        let kt = d.kt as f64;
        let flops = tile_m as f64 * d.width as f64 * kt; // 2*..*kt/2
        let bytes_in = tile_m as f64 * kt * eb / reuse_a
            + kt * d.width as f64 * (0.5 * eb + 0.0625) / reuse_b // compressed B + metadata
            + kt * 4.0 + d.width as f64 * 4.0;                    // CTO tables
        let bytes_out = tile_m as f64 * d.width as f64 * eb;
        for _ in 0..grid_m {
            all.push(TileWork { flops, bytes_in, bytes_out });
        }
    }
    Kernel { name: "tvw".into(), pipe, efficiency: cal.stc_eff, serialize_mem: false, tiles: all }
        .latency(specs)
}

/// TEW: the TW part on `tw_pipe` plus the delta-EW CSC remainder on CUDA
/// cores, launched on concurrent streams (§V / Fig. 7b).
#[allow(clippy::too_many_arguments)]
pub fn tew_latency(
    shape: GemmShape,
    tiles: &[TwTileDesc],
    g: usize,
    delta: f64,
    tw_pipe: Pipe,
    specs: &GpuSpecs,
    cal: &Calibration,
) -> f64 {
    let eff = match tw_pipe {
        Pipe::CudaFp32 => cal.tw_eff_cuda,
        _ => cal.tw_eff_tc,
    };
    let eb = tw_pipe.elem_bytes();
    let tile_m = adaptive_tile_m(shape.m, tiles.len().max(1), (128 * 128 / g).max(32), specs.sms);
    let grid_m = ceil_div(shape.m, tile_m);
    let grid_n = tiles.len().max(1);
    let (reuse_a, reuse_b) = reuse(grid_m, grid_n, specs.sms);
    let mut tw_tiles = Vec::new();
    for d in tiles {
        let kt = d.kt as f64;
        tw_tiles.push(TileWork {
            flops: 2.0 * tile_m as f64 * d.width as f64 * kt,
            bytes_in: tile_m as f64 * kt * eb / reuse_a
                + kt * d.width as f64 * eb / reuse_b
                + kt * 4.0
                + d.width as f64 * 4.0,
            bytes_out: tile_m as f64 * d.width as f64 * eb,
        });
    }
    let mut all_tw = Vec::with_capacity(tw_tiles.len() * grid_m);
    for t in &tw_tiles {
        for _ in 0..grid_m {
            all_tw.push(*t);
        }
    }
    let tw_kernel =
        Kernel { name: "tew-tw".into(), pipe: tw_pipe, efficiency: eff, serialize_mem: false, tiles: all_tw };
    let ew_kernel = ew_plan(shape, 1.0 - delta, specs, cal);
    concurrent_latency(&[tw_kernel, ew_kernel], specs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::specs::a100;

    const SHAPE: GemmShape = GemmShape { m: 4096, k: 4096, n: 4096 };

    fn cal() -> Calibration {
        Calibration::default()
    }

    #[test]
    fn anchor_dtc_over_cuda_about_9_7x() {
        let s = a100();
        let d_tc = dense_plan(SHAPE, Pipe::TensorFp16, &s, &cal()).latency(&s);
        let d_cuda = dense_plan(SHAPE, Pipe::CudaFp32, &s, &cal()).latency(&s);
        let ratio = d_cuda / d_tc;
        assert!((ratio - 9.7).abs() < 1.5, "DTC/CUDA ratio {ratio}");
    }

    #[test]
    fn anchor_vw4_about_1_67x() {
        let s = a100();
        let d = dense_plan(SHAPE, Pipe::TensorFp16, &s, &cal()).latency(&s);
        let v = vw24_plan(SHAPE, false, &s, &cal()).latency(&s);
        let ratio = d / v;
        assert!((ratio - 1.67).abs() < 0.2, "VW-4 speedup {ratio}");
    }

    #[test]
    fn anchor_tw128_crossover_near_10pct() {
        let s = a100();
        let d = dense_plan(SHAPE, Pipe::TensorFp16, &s, &cal()).latency(&s);
        let at = |sp: f64| {
            tw_latency(SHAPE, &tw_uniform_tiles(SHAPE, sp, 128), 128, Pipe::TensorFp16,
                       TwStrategy::FusedCto, &s, &cal())
        };
        assert!(at(0.05) > d, "TW slower than dense below crossover");
        assert!(at(0.20) < d, "TW faster than dense above crossover");
    }

    #[test]
    fn anchor_ew_crossover_near_95pct() {
        let s = a100();
        let d = dense_plan(SHAPE, Pipe::CudaFp32, &s, &cal()).latency(&s);
        assert!(ew_plan(SHAPE, 0.90, &s, &cal()).latency(&s) > d);
        assert!(ew_plan(SHAPE, 0.98, &s, &cal()).latency(&s) < d);
    }

    #[test]
    fn anchor_bw_crossovers() {
        let s = a100();
        let d = dense_plan(SHAPE, Pipe::TensorFp16, &s, &cal()).latency(&s);
        // BW-32 crosses near 40%
        assert!(bw_plan(SHAPE, 0.30, 32, &s, &cal()).latency(&s) > d);
        assert!(bw_plan(SHAPE, 0.50, 32, &s, &cal()).latency(&s) < d);
        // BW-16 crosses near 70%
        assert!(bw_plan(SHAPE, 0.60, 16, &s, &cal()).latency(&s) > d);
        assert!(bw_plan(SHAPE, 0.80, 16, &s, &cal()).latency(&s) < d);
    }

    #[test]
    fn anchor_int8() {
        let s = a100();
        let d = dense_plan(SHAPE, Pipe::TensorFp16, &s, &cal()).latency(&s);
        let i8d = dense_plan(SHAPE, Pipe::TensorInt8, &s, &cal()).latency(&s);
        let i8s = vw24_plan(SHAPE, true, &s, &cal()).latency(&s);
        assert!((d / i8d - 1.62).abs() < 0.25, "int8 dense {}", d / i8d);
        assert!((d / i8s - 2.16).abs() < 0.35, "int8 sparse {}", d / i8s);
    }

    #[test]
    fn tw_strategy_ladder_monotone() {
        let s = a100();
        let tiles = tw_uniform_tiles(SHAPE, 0.75, 128);
        let lat = |st| tw_latency(SHAPE, &tiles, 128, Pipe::TensorFp16, st, &s, &cal());
        let naive = lat(TwStrategy::Naive);
        let transposed = lat(TwStrategy::Transposed);
        let streams = lat(TwStrategy::BatchedStreams);
        let fused = lat(TwStrategy::FusedCto);
        assert!(naive > transposed, "{naive} vs {transposed}");
        assert!(transposed >= streams, "{transposed} vs {streams}");
        assert!(streams >= fused, "{streams} vs {fused}");
    }

    #[test]
    fn tvw_faster_than_tw_at_same_sparsity() {
        let s = a100();
        // iso-sparsity 75%: TVW uses TW 50% + 2:4
        let tw_tiles = tw_uniform_tiles(SHAPE, 0.75, 128);
        let tvw_tiles = tw_uniform_tiles(SHAPE, 0.50, 128);
        let tw = tw_latency(SHAPE, &tw_tiles, 128, Pipe::TensorFp16, TwStrategy::FusedCto, &s, &cal());
        let tvw = tvw_latency(SHAPE, &tvw_tiles, 128, &s, &cal());
        // both should beat dense; TVW within ~2x of TW either way
        let d = dense_plan(SHAPE, Pipe::TensorFp16, &s, &cal()).latency(&s);
        assert!(tw < d && tvw < d);
    }

    #[test]
    fn small_gemm_vw_no_speedup() {
        // the paper's CNN observation: small GEMMs are memory/launch bound,
        // so VW-4 gains little (~0.98x)
        let s = a100();
        let small = GemmShape::new(196, 512, 512);
        let d = dense_plan(small, Pipe::TensorFp16, &s, &cal()).latency(&s);
        let v = vw24_plan(small, false, &s, &cal()).latency(&s);
        let ratio = d / v;
        // (paper measures ~0.98x on CNN shapes; our model yields ~1.2-1.35 —
        // directionally collapsed relative to the 1.67x large-shape gain)
        assert!(ratio < 1.4, "small-shape VW speedup should collapse: {ratio}");
    }

    #[test]
    fn tew_latency_grows_with_delta() {
        let s = a100();
        let tiles = tw_uniform_tiles(SHAPE, 0.75, 128);
        let l1 = tew_latency(SHAPE, &tiles, 128, 0.01, Pipe::TensorFp16, &s, &cal());
        let l5 = tew_latency(SHAPE, &tiles, 128, 0.05, Pipe::TensorFp16, &s, &cal());
        let l10 = tew_latency(SHAPE, &tiles, 128, 0.10, Pipe::TensorFp16, &s, &cal());
        assert!(l1 < l5 && l5 < l10);
    }
}
