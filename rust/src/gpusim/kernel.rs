//! Kernel latency model: greedy list-scheduling of threadblock tiles over
//! SMs with a per-tile roofline (compute vs HBM traffic).
//!
//! The makespan scheduler naturally exposes the two effects the paper's §V
//! optimizations target: *load imbalance* from heterogeneous TW tiles and
//! *under-utilization* from kernels with fewer tiles than SMs — and shows
//! how batching/fusion (merging tile lists into one schedule) fixes both.

use super::specs::{GpuSpecs, Pipe};

/// One threadblock tile's work.
#[derive(Clone, Copy, Debug)]
pub struct TileWork {
    /// FLOPs (or int OPs) executed by this tile — *kept* work only.
    pub flops: f64,
    /// HBM bytes read, already adjusted for L2/wave reuse by the plan
    /// builder.
    pub bytes_in: f64,
    /// HBM bytes written.
    pub bytes_out: f64,
}

/// A GPU kernel: homogeneous pipe + efficiency, heterogeneous tiles.
#[derive(Clone, Debug)]
pub struct Kernel {
    pub name: String,
    pub pipe: Pipe,
    /// Fraction of pipe peak this kernel family achieves (calibrated).
    pub efficiency: f64,
    /// Uncoalesced access pattern: scattered loads/stores cannot be
    /// double-buffered behind compute, so memory time *adds* to compute
    /// time instead of overlapping (the Fig. 4 "naive tiling" pathology).
    pub serialize_mem: bool,
    pub tiles: Vec<TileWork>,
}

impl Kernel {
    /// Time one tile takes on one SM, given `active_sms` sharing HBM.
    fn tile_time(&self, t: &TileWork, specs: &GpuSpecs, active_sms: usize) -> f64 {
        let rate = self.pipe.rate(specs) * self.efficiency / specs.sms as f64;
        let bw = specs.hbm_bytes_per_sec / active_sms.max(1) as f64;
        let compute = t.flops / rate;
        let mem = (t.bytes_in + t.bytes_out) / bw;
        let body = if self.serialize_mem { compute + mem } else { compute.max(mem) };
        body + specs.tile_overhead
    }

    pub fn total_flops(&self) -> f64 {
        self.tiles.iter().map(|t| t.flops).sum()
    }

    pub fn total_bytes(&self) -> f64 {
        self.tiles.iter().map(|t| t.bytes_in + t.bytes_out).sum()
    }

    /// Simulated execution latency (seconds) of this kernel alone.
    pub fn latency(&self, specs: &GpuSpecs) -> f64 {
        specs.launch_overhead + makespan(std::slice::from_ref(self), specs)
    }
}

/// Greedy list-scheduling makespan of a set of kernels' tiles over the
/// SMs.  Tiles are taken longest-first (LPT); each SM takes the next tile
/// when free.  `active_sms` for the bandwidth share is the number of SMs
/// that actually receive work.
pub fn makespan(kernels: &[Kernel], specs: &GpuSpecs) -> f64 {
    let mut times: Vec<f64> = Vec::new();
    let total_tiles: usize = kernels.iter().map(|k| k.tiles.len()).sum();
    if total_tiles == 0 {
        return 0.0;
    }
    let active = total_tiles.min(specs.sms);
    for k in kernels {
        for t in &k.tiles {
            times.push(k.tile_time(t, specs, active));
        }
    }
    // LPT list scheduling over `sms` machines via a simple binary heap of
    // machine loads (smallest load first).
    times.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut loads = std::collections::BinaryHeap::with_capacity(specs.sms);
    for _ in 0..specs.sms {
        loads.push(std::cmp::Reverse(OrderedF64(0.0)));
    }
    for t in times {
        let std::cmp::Reverse(OrderedF64(l)) = loads.pop().unwrap();
        loads.push(std::cmp::Reverse(OrderedF64(l + t)));
    }
    loads
        .into_iter()
        .map(|std::cmp::Reverse(OrderedF64(l))| l)
        .fold(0.0, f64::max)
}

/// Latency of kernels launched back-to-back in one stream.
pub fn sequential_latency(kernels: &[Kernel], specs: &GpuSpecs) -> f64 {
    kernels.iter().map(|k| k.latency(specs)).sum()
}

/// Latency of kernels launched on concurrent streams: the SM scheduler
/// fills from all kernels' tiles, but the host still dispatches launches
/// serially — so stream execution pays one launch overhead per kernel
/// while fused execution (a single kernel) pays exactly one.  This gap is
/// the paper's Fig. 4 step 5→6 fusion gain.
pub fn concurrent_latency(kernels: &[Kernel], specs: &GpuSpecs) -> f64 {
    if kernels.is_empty() {
        return 0.0;
    }
    specs.launch_overhead * kernels.len() as f64 + makespan(kernels, specs)
}

#[derive(PartialEq)]
struct OrderedF64(f64);
impl Eq for OrderedF64 {}
impl PartialOrd for OrderedF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrderedF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap_or(std::cmp::Ordering::Equal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::specs::a100;

    fn uniform_kernel(n: usize, flops: f64, bytes: f64) -> Kernel {
        Kernel {
            name: "test".into(),
            pipe: Pipe::TensorFp16,
            efficiency: 1.0,
            serialize_mem: false,
            tiles: vec![TileWork { flops, bytes_in: bytes, bytes_out: 0.0 }; n],
        }
    }

    #[test]
    fn makespan_scales_with_waves() {
        let s = a100();
        let one_wave = uniform_kernel(108, 1e8, 0.0).latency(&s);
        let two_waves = uniform_kernel(216, 1e8, 0.0).latency(&s);
        let ratio = (two_waves - s.launch_overhead) / (one_wave - s.launch_overhead);
        assert!((ratio - 2.0).abs() < 0.01, "{ratio}");
    }

    #[test]
    fn under_utilization_visible() {
        let s = a100();
        // 10 tiles on 108 SMs: same latency as 1 tile (all parallel)
        let k10 = uniform_kernel(10, 1e8, 0.0).latency(&s);
        let k1 = uniform_kernel(1, 1e8, 0.0).latency(&s);
        assert!((k10 - k1).abs() / k1 < 1e-9);
    }

    #[test]
    fn concurrent_beats_sequential_for_small_kernels() {
        let s = a100();
        let kernels: Vec<Kernel> = (0..8).map(|_| uniform_kernel(16, 1e8, 0.0)).collect();
        let seq = sequential_latency(&kernels, &s);
        let conc = concurrent_latency(&kernels, &s);
        assert!(conc < seq / 2.0, "seq={seq} conc={conc}");
    }

    #[test]
    fn load_imbalance_hurts() {
        let s = a100();
        // same total work, one mix balanced / one skewed
        let balanced = uniform_kernel(108, 1e8, 0.0);
        let mut skewed = uniform_kernel(107, 0.5e8, 0.0);
        skewed.tiles.push(TileWork { flops: 54.5e8, bytes_in: 0.0, bytes_out: 0.0 });
        assert!(skewed.latency(&s) > balanced.latency(&s) * 1.5);
    }

    #[test]
    fn memory_bound_tiles_use_roofline() {
        let s = a100();
        // huge traffic, trivial compute: latency tracks bytes/bandwidth
        let k = uniform_kernel(108, 1.0, 1e7);
        let lat = k.latency(&s) - s.launch_overhead;
        let expected = 1e7 / (s.hbm_bytes_per_sec / 108.0) + s.tile_overhead;
        assert!((lat - expected).abs() / expected < 0.01);
    }
}
