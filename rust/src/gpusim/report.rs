//! Utilization reporting: turn a simulated kernel into the numbers a
//! profiler would show — achieved throughput, fraction of pipe peak,
//! occupancy, and the binding resource — used by the `simulate-model`
//! CLI for per-layer breakdowns.

use super::kernel::Kernel;
use super::specs::GpuSpecs;

/// What limits a kernel in the model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory,
    Occupancy,
    Overhead,
}

impl Bound {
    pub fn label(&self) -> &'static str {
        match self {
            Bound::Compute => "compute",
            Bound::Memory => "memory",
            Bound::Occupancy => "occupancy",
            Bound::Overhead => "overhead",
        }
    }
}

/// Profiler-style summary of one simulated kernel.
#[derive(Clone, Debug)]
pub struct KernelReport {
    pub name: String,
    pub latency: f64,
    /// Achieved (kept-)FLOP/s.
    pub achieved_flops: f64,
    /// Fraction of the pipe's calibrated-efficiency peak.
    pub peak_fraction: f64,
    /// Mean busy fraction of the SMs over the kernel's lifetime.
    pub occupancy: f64,
    pub bound: Bound,
    pub tiles: usize,
}

/// Build the report for one kernel.
pub fn report(kernel: &Kernel, specs: &GpuSpecs) -> KernelReport {
    let latency = kernel.latency(specs);
    let flops = kernel.total_flops();
    let achieved = flops / latency.max(1e-12);
    let pipe_rate = kernel.pipe.rate(specs) * kernel.efficiency;
    let peak_fraction = achieved / pipe_rate;

    // occupancy: total tile-busy time over (latency x SMs)
    let active = kernel.tiles.len().min(specs.sms);
    let busy: f64 = kernel
        .tiles
        .iter()
        .map(|t| {
            let rate = pipe_rate / specs.sms as f64;
            let bw = specs.hbm_bytes_per_sec / active.max(1) as f64;
            let compute = t.flops / rate;
            let mem = (t.bytes_in + t.bytes_out) / bw;
            if kernel.serialize_mem { compute + mem } else { compute.max(mem) }
        })
        .sum();
    let occupancy = (busy / (latency * specs.sms as f64)).min(1.0);

    // binding resource: compare aggregate compute vs memory vs overhead time
    let compute_time: f64 = flops / pipe_rate;
    let mem_time: f64 = kernel.total_bytes() / specs.hbm_bytes_per_sec;
    let overhead = specs.launch_overhead + kernel.tiles.len() as f64 * specs.tile_overhead
        / specs.sms as f64;
    let bound = if kernel.tiles.len() < specs.sms / 2 && occupancy < 0.5 {
        Bound::Occupancy
    } else if overhead > compute_time.max(mem_time) {
        Bound::Overhead
    } else if mem_time > compute_time {
        Bound::Memory
    } else {
        Bound::Compute
    };

    KernelReport {
        name: kernel.name.clone(),
        latency,
        achieved_flops: achieved,
        peak_fraction,
        occupancy,
        bound,
        tiles: kernel.tiles.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::plans::{dense_plan, ew_plan, GemmShape};
    use crate::gpusim::specs::{a100, Calibration, Pipe};

    #[test]
    fn big_dense_is_compute_bound_high_occupancy() {
        let s = a100();
        let k = dense_plan(GemmShape::new(4096, 4096, 4096), Pipe::TensorFp16, &s,
                           &Calibration::default());
        let r = report(&k, &s);
        assert_eq!(r.bound, Bound::Compute);
        assert!(r.occupancy > 0.9, "{}", r.occupancy);
        assert!(r.peak_fraction > 0.8, "{}", r.peak_fraction);
    }

    #[test]
    fn tiny_gemm_is_occupancy_bound() {
        let s = a100();
        let k = dense_plan(GemmShape::new(32, 64, 64), Pipe::TensorFp16, &s,
                           &Calibration::default());
        let r = report(&k, &s);
        assert_eq!(r.bound, Bound::Occupancy);
    }

    #[test]
    fn ew_never_reaches_compute_peak() {
        let s = a100();
        let k = ew_plan(GemmShape::new(4096, 4096, 4096), 0.9, &s, &Calibration::default());
        let r = report(&k, &s);
        assert!(r.peak_fraction < 1.0);
        assert!(r.achieved_flops < 19.5e12);
    }
}
