//! Analytical A100-class latency simulator (the GPU-substitution substrate;
//! see DESIGN.md §1).
//!
//! Structure: `specs` holds hardware constants + calibrated per-family
//! efficiencies; `kernel` schedules threadblock tiles over SMs with a
//! per-tile roofline; `plans` builds the tile lists for every sparsity
//! pattern and execution strategy in the paper's evaluation.

pub mod kernel;
pub mod plans;
pub mod report;
pub mod specs;

pub use kernel::{concurrent_latency, makespan, sequential_latency, Kernel, TileWork};
pub use plans::{
    bw_plan, dense_plan, ew_plan, tew_latency, tvw_latency, tw_latency, tw_tiles_from_plan,
    tw_uniform_tiles, vw24_plan, GemmShape, TwStrategy, TwTileDesc,
};
pub use report::{report, Bound, KernelReport};
pub use specs::{a100, Calibration, GpuSpecs, Pipe};
