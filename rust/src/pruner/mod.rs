//! The multi-stage pruning driver (Algorithm 1) and the global cross-layer
//! sparsity-budget allocator (paper §IV "Global Weight Pruning").

use crate::sparse::{Mask, Pattern};
use crate::tensor::Matrix;
use crate::util::argsort_desc_by;

/// One prune→fine-tune stage record.
#[derive(Clone, Debug)]
pub struct StageReport {
    pub target_sparsity: f64,
    pub achieved_sparsity: f64,
}

/// Multi-stage schedule: raise sparsity by `step` per stage until `target`
/// (Algorithm 1).  `fine_tune` is invoked after every stage with the masked
/// weights and may adjust surviving values (the accuracy-recovery step).
pub struct MultiStagePruner {
    pub pattern: Pattern,
    pub target: f64,
    pub step: f64,
}

impl MultiStagePruner {
    pub fn new(pattern: Pattern, target: f64, step: f64) -> Self {
        assert!(step > 0.0 && target >= 0.0 && target < 1.0);
        Self { pattern, target, step }
    }

    /// Run the schedule on one weight matrix.  Returns the final weights,
    /// final mask, and per-stage reports.
    pub fn run<F>(&self, w: &Matrix, mut fine_tune: F) -> (Matrix, Mask, Vec<StageReport>)
    where
        F: FnMut(&mut Matrix, &Mask),
    {
        let mut w = w.clone();
        let mut mask = Mask::all(w.rows, w.cols);
        let mut reports = Vec::new();
        let mut s_t = 0.0f64;
        while s_t < self.target - 1e-9 {
            s_t = (s_t + self.step).min(self.target);
            // TVW cannot express sparsity < 0.5; ramp through TW until then
            let eff = match self.pattern {
                Pattern::Tvw { g, .. } if s_t < 0.5 => Pattern::Tw { g },
                p => p,
            };
            mask = eff.prune(&w, s_t);
            w = mask.apply(&w);
            fine_tune(&mut w, &mask);
            w = mask.apply(&w); // fine-tune must not resurrect pruned weights
            reports.push(StageReport { target_sparsity: s_t, achieved_sparsity: mask.sparsity() });
        }
        (w, mask, reports)
    }
}

/// Global cross-layer budget allocation: rank all layers' pruning units by
/// importance in one pool, so layers with redundant weights absorb more of
/// the budget (paper §IV).  Works at column granularity, which is the
/// pattern-agnostic unit shared by TW-C of all layers.
///
/// Returns per-layer sparsity targets whose weighted mean equals `target`.
pub fn allocate_global_budget(layers: &[&Matrix], target: f64) -> Vec<f64> {
    // score every column of every layer, normalised per layer to make
    // magnitudes comparable (different layers have different scales)
    struct Unit {
        layer: usize,
        score: f64,
        elems: usize,
    }
    let mut units: Vec<Unit> = Vec::new();
    for (li, w) in layers.iter().enumerate() {
        let mut col_scores: Vec<f64> = (0..w.cols)
            .map(|c| (0..w.rows).map(|r| w.at(r, c).abs() as f64).sum::<f64>())
            .collect();
        let mean = col_scores.iter().sum::<f64>() / col_scores.len().max(1) as f64;
        for s in &mut col_scores {
            *s /= mean.max(1e-12);
        }
        for s in col_scores {
            units.push(Unit { layer: li, score: s, elems: w.rows });
        }
    }
    let total_elems: usize = units.iter().map(|u| u.elems).sum();
    let budget = (target * total_elems as f64) as usize;
    // prune lowest-scoring units first until the budget is consumed
    let order = argsort_desc_by(units.len(), |i| -units[i].score);
    let mut pruned_per_layer = vec![0usize; layers.len()];
    let mut pruned = 0usize;
    for &i in &order {
        if pruned >= budget {
            break;
        }
        pruned += units[i].elems;
        pruned_per_layer[units[i].layer] += units[i].elems;
    }
    layers
        .iter()
        .enumerate()
        .map(|(li, w)| {
            let total = w.rows * w.cols;
            // cap so no layer is fully destroyed (the ResNet-50 lesson from
            // the paper's §VI-C: leaving small layers lightly pruned helps)
            (pruned_per_layer[li] as f64 / total as f64).min(0.98)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn multi_stage_reaches_target() {
        let w = Matrix::randn(64, 64, &mut Rng::new(60));
        let pruner = MultiStagePruner::new(Pattern::Tw { g: 16 }, 0.75, 0.25);
        let (_, mask, reports) = pruner.run(&w, |_, _| {});
        assert_eq!(reports.len(), 3);
        assert!((mask.sparsity() - 0.75).abs() < 0.05);
    }

    #[test]
    fn stages_monotone() {
        let w = Matrix::randn(64, 64, &mut Rng::new(61));
        let pruner = MultiStagePruner::new(Pattern::Ew, 0.9, 0.3);
        let (_, _, reports) = pruner.run(&w, |_, _| {});
        for win in reports.windows(2) {
            assert!(win[1].achieved_sparsity >= win[0].achieved_sparsity - 1e-9);
        }
    }

    #[test]
    fn fine_tune_cannot_resurrect() {
        let w = Matrix::randn(32, 32, &mut Rng::new(62));
        let pruner = MultiStagePruner::new(Pattern::Ew, 0.5, 0.5);
        let (wf, mask, _) = pruner.run(&w, |w, _| {
            for v in &mut w.data {
                *v += 1.0; // adversarial fine-tune writing into pruned slots
            }
        });
        for (v, k) in wf.data.iter().zip(&mask.keep) {
            if !*k {
                assert_eq!(*v, 0.0);
            }
        }
    }

    #[test]
    fn tvw_ramp_through_tw() {
        let w = Matrix::randn(64, 64, &mut Rng::new(63));
        let pruner = MultiStagePruner::new(Pattern::Tvw { g: 16, m: 4 }, 0.75, 0.25);
        let (_, mask, reports) = pruner.run(&w, |_, _| {});
        assert_eq!(reports.len(), 3);
        assert!((mask.sparsity() - 0.75).abs() < 0.05);
    }

    #[test]
    fn global_budget_prefers_redundant_layers() {
        let mut rng = Rng::new(64);
        let important = Matrix::randn(64, 64, &mut rng); // unit scale
        let mut redundant = Matrix::randn(64, 64, &mut rng);
        // make half of redundant's columns tiny -> clearly prunable
        for r in 0..64 {
            for c in 0..32 {
                *redundant.at_mut(r, c) *= 0.01;
            }
        }
        let targets = allocate_global_budget(&[&important, &redundant], 0.25);
        assert!(
            targets[1] > targets[0],
            "redundant layer should absorb more budget: {targets:?}"
        );
        // weighted mean ~ target
        let mean = (targets[0] + targets[1]) / 2.0;
        assert!((mean - 0.25).abs() < 0.1, "{mean}");
    }

    #[test]
    fn global_budget_extremes() {
        let w1 = Matrix::randn(32, 32, &mut Rng::new(65));
        let w2 = Matrix::randn(32, 32, &mut Rng::new(66));
        let t0 = allocate_global_budget(&[&w1, &w2], 0.0);
        assert!(t0.iter().all(|&t| t == 0.0));
        let t9 = allocate_global_budget(&[&w1, &w2], 0.9);
        assert!(t9.iter().all(|&t| t > 0.5));
    }
}
