//! The search driver: enumerate → analytically pre-filter → measure →
//! persist, per GEMM shape and per model-zoo workload.

use std::collections::BTreeSet;

use super::cache::{PlanCache, PlanKey, TunedEntry};
use super::measure::{bench_candidate, BenchData, MeasureOpts};
use super::model::prefilter;
use super::space::{Candidate, PatternFamily, SearchSpace};
use crate::gpusim::{a100, Calibration, GemmShape, GpuSpecs};
use crate::models::ModelWorkload;

/// Tuning policy.
#[derive(Clone, Debug)]
pub struct TunerOpts {
    /// Target sparsity the pattern families are tuned at.
    pub sparsity: f64,
    /// Candidate axes.
    pub space: SearchSpace,
    /// Sampling policy per measured candidate.
    pub measure: MeasureOpts,
    /// Analytical pre-filter: keep candidates within `slack`x of the
    /// modeled best.
    pub slack: f64,
    /// Pre-filter cap: at most this many candidates are measured per
    /// (shape, family).
    pub max_measured: usize,
    /// Cap the activation row count during measurement (GEMM cost is
    /// linear in M, so tuning at a reduced M transfers; `None` = full M).
    pub m_cap: Option<usize>,
    /// Thread budget (the cache key's `nthreads`); > 1 adds parallel
    /// kernel variants to the space.
    pub nthreads: usize,
    /// Operand seed (deterministic tuning inputs).
    pub seed: u64,
}

impl Default for TunerOpts {
    fn default() -> Self {
        TunerOpts {
            sparsity: 0.75,
            space: SearchSpace::default(),
            measure: MeasureOpts::default(),
            slack: 4.0,
            max_measured: 8,
            m_cap: Some(256),
            nthreads: 1,
            seed: 0xA107,
        }
    }
}

/// Outcome of tuning one (shape, family).
#[derive(Clone, Debug)]
pub struct ShapeResult {
    pub entry: TunedEntry,
    pub candidates_enumerated: usize,
    pub candidates_measured: usize,
}

/// The tuner: owns the cost-model substrate and the tuning policy.
pub struct Tuner {
    pub specs: GpuSpecs,
    pub cal: Calibration,
    pub opts: TunerOpts,
}

impl Tuner {
    pub fn new(opts: TunerOpts) -> Tuner {
        Tuner { specs: a100(), cal: Calibration::default(), opts }
    }

    fn capped(&self, shape: GemmShape) -> GemmShape {
        match self.opts.m_cap {
            Some(cap) if shape.m > cap.max(1) => GemmShape::new(cap.max(1), shape.k, shape.n),
            _ => shape,
        }
    }

    /// Tune one GEMM under one pattern family.  Returns `None` only when
    /// nothing in the family can execute the shape (e.g. 2:4 on K%4 != 0).
    pub fn tune_gemm(&self, shape: GemmShape, family: PatternFamily) -> Option<ShapeResult> {
        let shape = self.capped(shape);
        let sparsity = if family == PatternFamily::Dense { 0.0 } else { self.opts.sparsity };
        let space = self.opts.space.clone().with_threads(self.opts.nthreads);
        let cands = space.candidates(shape, family);
        let enumerated = cands.len();
        let kept = prefilter(
            &cands,
            shape,
            sparsity,
            self.opts.slack,
            self.opts.max_measured,
            &self.specs,
            &self.cal,
        );

        let mut data = BenchData::new(shape, sparsity, self.opts.seed);

        // the historical default is always measured: it is the speedup
        // baseline and a safety net against a mis-modeled filter
        let default_cand = Candidate::default_for(family);
        let default_meas = bench_candidate(&mut data, &default_cand, &self.opts.measure)?;
        let default_model = super::model::analytical_cost(
            shape,
            sparsity,
            &default_cand,
            &self.specs,
            &self.cal,
        );

        let mut best: (Candidate, f64, f64) =
            (default_cand, default_meas.mean_secs, default_model);
        let mut measured = 1usize;
        for (cand, model_cost) in &kept {
            if *cand == default_cand {
                continue; // already timed
            }
            let Some(meas) = bench_candidate(&mut data, cand, &self.opts.measure) else {
                continue;
            };
            measured += 1;
            if meas.mean_secs < best.1 {
                best = (*cand, meas.mean_secs, *model_cost);
            }
        }

        let (win, win_secs, win_model) = best;
        let entry = TunedEntry {
            key: PlanKey::new(shape, family.label(), sparsity, self.opts.nthreads),
            variant: win.variant.label().to_string(),
            bm: win.tile.bm,
            bk: win.tile.bk,
            g: win.g,
            threads: win.threads,
            micro: win.tile.micro.label(),
            precision: win.precision.label().to_string(),
            measured_us: win_secs * 1e6,
            model_us: win_model * 1e6,
            default_us: default_meas.mean_secs * 1e6,
        };
        Some(ShapeResult { entry, candidates_enumerated: enumerated, candidates_measured: measured })
    }

    /// Tune every distinct prunable GEMM shape of a workload under
    /// `families`, insert the winners into a fresh [`PlanCache`], and
    /// derive the workload-level serving recommendation (lowest summed
    /// tuned latency across the shapes, weighted by layer repetition).
    pub fn tune_workload(
        &self,
        workload: &ModelWorkload,
        model_key: &str,
        families: &[PatternFamily],
    ) -> (PlanCache, Vec<ShapeResult>) {
        let mut cache = PlanCache::new();
        let mut results = Vec::new();

        // distinct prunable shapes with their total repetition counts
        let mut shapes: BTreeSet<(usize, usize, usize)> = BTreeSet::new();
        for layer in workload.prunable_layers() {
            shapes.insert((layer.shape.m, layer.shape.k, layer.shape.n));
        }
        let weight = |m: usize, k: usize, n: usize| -> f64 {
            workload
                .prunable_layers()
                .filter(|l| (l.shape.m, l.shape.k, l.shape.n) == (m, k, n))
                .map(|l| l.count as f64)
                .sum()
        };

        // per-family summed tuned latency over the workload
        let mut family_totals: Vec<(PatternFamily, f64)> = Vec::new();
        for &family in families {
            let mut total = 0.0f64;
            let mut complete = true;
            for &(m, k, n) in &shapes {
                let shape = GemmShape::new(m, k, n);
                match self.tune_gemm(shape, family) {
                    Some(res) => {
                        total += res.entry.measured_us * weight(m, k, n);
                        cache.insert(res.entry.clone());
                        results.push(res);
                    }
                    None => complete = false,
                }
            }
            if complete && family.serving_variant().is_some() {
                family_totals.push((family, total));
            }
        }

        if let Some((best_family, _)) = family_totals
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
        {
            if let Some(variant) = best_family.serving_variant() {
                cache.set_model_variant(model_key, variant);
            }
        }
        (cache, results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> TunerOpts {
        TunerOpts {
            measure: MeasureOpts { warmup: 0, min_iters: 1, max_iters: 1, budget_secs: 0.0, trim_frac: 0.0 },
            max_measured: 3,
            m_cap: Some(16),
            space: SearchSpace {
                bms: vec![16, 32],
                bks: vec![64],
                gs: vec![16, 32],
                threads: vec![1],
                ..SearchSpace::default()
            },
            ..TunerOpts::default()
        }
    }

    #[test]
    fn tune_gemm_beats_or_matches_default() {
        let tuner = Tuner::new(quick_opts());
        let res = tuner.tune_gemm(GemmShape::new(64, 96, 80), PatternFamily::Tw).unwrap();
        assert_eq!(res.entry.key.pattern, "TW");
        assert!(res.entry.measured_us <= res.entry.default_us * 1.000001,
                "winner {} vs default {}", res.entry.measured_us, res.entry.default_us);
        assert!(res.candidates_measured >= 1);
        assert!(res.candidates_enumerated >= res.candidates_measured);
        assert!(res.entry.candidate().is_some());
    }

    #[test]
    fn tune_workload_fills_cache_and_recommends() {
        use crate::models::{GemmLayer, LayerKind};
        let tuner = Tuner::new(quick_opts());
        let layer = |name: &str, m: usize, k: usize, n: usize, count: usize, prunable: bool| {
            GemmLayer {
                name: name.into(),
                shape: GemmShape::new(m, k, n),
                count,
                prunable,
                kind: LayerKind::Fc,
            }
        };
        let tiny = ModelWorkload {
            name: "tiny",
            metric: "acc",
            layers: vec![
                layer("l0", 16, 64, 64, 1, false),
                layer("l1", 16, 64, 96, 2, true),
                layer("l2", 16, 96, 64, 1, true),
            ],
        };
        let (cache, results) =
            tuner.tune_workload(&tiny, "tiny", &[PatternFamily::Dense, PatternFamily::Tw]);
        // 2 distinct prunable shapes x 2 families
        assert_eq!(results.len(), 4);
        assert_eq!(cache.len(), 4);
        let rec = cache.model_variant("tiny").expect("recommendation set");
        assert!(rec == "model_dense" || rec == "model_tw", "{rec}");
        // every entry is resolvable back to an executable candidate and
        // carries a valid precision label (the axis the serving-side
        // `Precision::Auto` resolution reads back)
        for e in cache.entries() {
            assert!(e.candidate().is_some());
            assert!(e.measured_us > 0.0);
            assert!(e.precision == "fp32" || e.precision == "int8", "{}", e.precision);
        }
    }

    #[test]
    fn precision_axis_is_searched_and_persisted() {
        use crate::quant::Precision;
        let tuner = Tuner::new(quick_opts());
        let res = tuner.tune_gemm(GemmShape::new(16, 64, 64), PatternFamily::Dense).unwrap();
        // the winner (whichever precision it is) round-trips through the
        // entry back into an executable candidate of that precision
        let cand = res.entry.candidate().expect("resolvable");
        assert_eq!(cand.precision.label(), res.entry.precision);
        assert_ne!(cand.precision, Precision::Auto);
    }

    #[test]
    fn m_cap_applies() {
        let tuner = Tuner::new(TunerOpts { m_cap: Some(8), ..quick_opts() });
        let res = tuner.tune_gemm(GemmShape::new(4096, 64, 64), PatternFamily::Dense).unwrap();
        assert_eq!(res.entry.key.m, 8);
    }
}
