//! Empirical kernel autotuner + persistent plan cache.
//!
//! The paper's speedups hinge on picking the right tile shape and
//! execution strategy per GEMM: tile-wise sparsity lives or dies by the
//! tile granularity chosen at the global-memory level, and TVW adds a
//! register-level 2:4 dimension on top.  This layer searches the
//! (kernel variant × tile shape × pattern granularity × thread count ×
//! microkernel × numeric precision) space for each GEMM workload and
//! persists the winners:
//!
//! - [`space`] — candidate enumeration over [`crate::gemm::TileConfig`],
//!   TW granularity G, kernel variant, and thread count
//! - [`model`] — `gpusim`-analytical pre-filter that prunes the candidate
//!   set before anything is timed
//! - [`measure`] — wall-clock microbenchmark harness (warmup + trimmed
//!   mean) over real pruned operands
//! - [`cache`] — persistent plan cache keyed by
//!   `(M, K, N, pattern, sparsity, nthreads)`, serialized via [`crate::json`]
//! - [`tuner`] — the search driver: enumerate → pre-filter → measure →
//!   cache, per layer shape and per model workload
//!
//! The serving stack consumes the output: `coordinator::Server` loads a
//! tuned [`PlanCache`] at startup and `Policy::Tuned` routes requests to
//! the variant the tuner recommended (see `docs/autotune.md` for the
//! cache schema and invalidation rule).

pub mod cache;
pub mod measure;
pub mod model;
pub mod space;
pub mod tuner;

pub use cache::{PlanCache, PlanKey, TunedEntry, SCHEMA_VERSION};
pub use measure::{bench_candidate, measure, BenchData, MeasureOpts, Measurement};
pub use model::{analytical_cost, prefilter};
pub use space::{Candidate, KernelVariant, PatternFamily, SearchSpace};
pub use tuner::{ShapeResult, Tuner, TunerOpts};
