//! Persistent plan cache: the tuner's output, serialized via `crate::json`
//! so a tuning run survives process restarts and the serving stack can
//! load it at startup.
//!
//! Schema (see `docs/autotune.md`): a `schema_version` header (the
//! invalidation rule — a loader that sees any other version discards the
//! file), a flat `entries` list keyed by `(M, K, N, pattern, sparsity,
//! nthreads)`, and a `models` map of per-workload serving recommendations.

use std::collections::BTreeMap;
use std::path::Path;

use super::space::{Candidate, KernelVariant};
use crate::error::{Context, Result};
use crate::gemm::{MicroCfg, TileConfig};
use crate::gpusim::GemmShape;
use crate::json::{arr, num, obj, s, Json};
use crate::quant::Precision;
use crate::{anyhow, bail};

/// Bump on any incompatible change to the cache layout or to the meaning
/// of tuned parameters; stale caches are discarded wholesale on load.
/// v2: entries carry the tuned microkernel request (`micro` label).
/// v3: entries carry the tuned numeric precision (`precision` label).
pub const SCHEMA_VERSION: u64 = 3;

/// Cache key: one GEMM problem as tuned.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PlanKey {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Pattern family label (`DENSE` / `TW` / `TVW` / `VW-4`).
    pub pattern: String,
    /// Sparsity in basis points (7500 = 75%), keeping the key integral.
    pub sparsity_bp: u32,
    /// Thread budget the tuning ran under.
    pub nthreads: usize,
}

impl PlanKey {
    pub fn new(shape: GemmShape, pattern: &str, sparsity: f64, nthreads: usize) -> PlanKey {
        PlanKey {
            m: shape.m,
            k: shape.k,
            n: shape.n,
            pattern: pattern.to_string(),
            sparsity_bp: (sparsity * 10_000.0).round().clamp(0.0, 10_000.0) as u32,
            nthreads,
        }
    }

    /// Stable string id used as the map key.
    pub fn id(&self) -> String {
        format!(
            "{}x{}x{}|{}|s{}|t{}",
            self.m, self.k, self.n, self.pattern, self.sparsity_bp, self.nthreads
        )
    }
}

/// One tuned decision: the winning candidate plus its evidence.
#[derive(Clone, Debug)]
pub struct TunedEntry {
    pub key: PlanKey,
    /// Winning kernel variant (`KernelVariant::label()`).
    pub variant: String,
    pub bm: usize,
    pub bk: usize,
    pub g: usize,
    pub threads: usize,
    /// Winning microkernel request ([`MicroCfg::label`]: "auto" /
    /// "scalar" / "simd{MR}x{NR}").
    pub micro: String,
    /// Winning numeric precision ([`Precision::label`]: "fp32" / "int8";
    /// "auto" never persists — the tuner stores what actually won).
    pub precision: String,
    /// Trimmed-mean measured latency of the winner, microseconds.
    pub measured_us: f64,
    /// gpusim pre-filter estimate for the winner, microseconds.
    pub model_us: f64,
    /// Measured latency of the family's historical default config,
    /// microseconds (the speedup baseline).
    pub default_us: f64,
}

impl TunedEntry {
    pub fn speedup(&self) -> f64 {
        if self.measured_us > 0.0 {
            self.default_us / self.measured_us
        } else {
            1.0
        }
    }

    /// The tuned microkernel request (`Auto` when the label fails to
    /// parse — `validate` rejects that case at load time).
    pub fn micro_cfg(&self) -> MicroCfg {
        MicroCfg::from_label(&self.micro).unwrap_or(MicroCfg::Auto)
    }

    /// The full tuned tile config, microkernel included.
    pub fn tile(&self) -> TileConfig {
        TileConfig::new(self.bm, self.bk).with_micro(self.micro_cfg())
    }

    /// The tuned numeric precision (`Fp32` when the label fails to parse
    /// — `validate` rejects that case at load time).
    pub fn precision_value(&self) -> Precision {
        Precision::from_label(&self.precision).unwrap_or(Precision::Fp32)
    }

    /// Reconstruct the winning candidate (for re-execution).
    pub fn candidate(&self) -> Option<Candidate> {
        Some(Candidate {
            variant: KernelVariant::from_label(&self.variant)?,
            tile: self.tile(),
            g: self.g,
            threads: self.threads,
            precision: self.precision_value(),
        })
    }

    /// Reject entries no kernel could honour — a stale or hand-edited
    /// cache must fail loudly at load time, not silently mis-tile every
    /// request routed through it (`docs/DESIGN.md` §9).
    pub fn validate(&self) -> Result<()> {
        let id = self.key.id();
        TileConfig::new(self.bm, self.bk)
            .validate(&self.key.pattern)
            .map_err(|e| anyhow!("plan cache entry {id}: {e}"))?;
        if MicroCfg::from_label(&self.micro).is_none() {
            bail!("plan cache entry {id}: unknown microkernel label {:?}", self.micro);
        }
        if Precision::from_label(&self.precision).is_none() {
            bail!("plan cache entry {id}: unknown precision label {:?}", self.precision);
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("m", num(self.key.m as f64)),
            ("k", num(self.key.k as f64)),
            ("n", num(self.key.n as f64)),
            ("pattern", s(&self.key.pattern)),
            ("sparsity_bp", num(self.key.sparsity_bp as f64)),
            ("nthreads", num(self.key.nthreads as f64)),
            ("variant", s(&self.variant)),
            ("bm", num(self.bm as f64)),
            ("bk", num(self.bk as f64)),
            ("g", num(self.g as f64)),
            ("threads", num(self.threads as f64)),
            ("micro", s(&self.micro)),
            ("precision", s(&self.precision)),
            ("measured_us", num(self.measured_us)),
            ("model_us", num(self.model_us)),
            ("default_us", num(self.default_us)),
        ])
    }

    fn from_json(v: &Json) -> Result<TunedEntry> {
        let field = |name: &str| -> Result<f64> {
            v.get(name).and_then(Json::as_f64).context(format!("entry missing {name:?}"))
        };
        let key = PlanKey {
            m: field("m")? as usize,
            k: field("k")? as usize,
            n: field("n")? as usize,
            pattern: v
                .get("pattern")
                .and_then(Json::as_str)
                .context("entry missing \"pattern\"")?
                .to_string(),
            sparsity_bp: field("sparsity_bp")? as u32,
            nthreads: field("nthreads")? as usize,
        };
        let entry = TunedEntry {
            key,
            variant: v
                .get("variant")
                .and_then(Json::as_str)
                .context("entry missing \"variant\"")?
                .to_string(),
            bm: field("bm")? as usize,
            bk: field("bk")? as usize,
            g: field("g")? as usize,
            threads: field("threads")? as usize,
            micro: v
                .get("micro")
                .and_then(Json::as_str)
                .context("entry missing \"micro\"")?
                .to_string(),
            precision: v
                .get("precision")
                .and_then(Json::as_str)
                .unwrap_or("fp32")
                .to_string(),
            measured_us: field("measured_us")?,
            model_us: field("model_us")?,
            default_us: field("default_us")?,
        };
        entry.validate()?;
        Ok(entry)
    }
}

/// The persistent cache.
#[derive(Clone, Debug, Default)]
pub struct PlanCache {
    entries: BTreeMap<String, TunedEntry>,
    /// Per-workload serving recommendation: model name → executable
    /// variant ("model_dense" / "model_tw" / "model_tvw").
    models: BTreeMap<String, String>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn insert(&mut self, entry: TunedEntry) {
        self.entries.insert(entry.key.id(), entry);
    }

    pub fn get(&self, key: &PlanKey) -> Option<&TunedEntry> {
        self.entries.get(&key.id())
    }

    pub fn entries(&self) -> impl Iterator<Item = &TunedEntry> {
        self.entries.values()
    }

    /// Tuned cache-blocking for one GEMM problem, if the cache holds a
    /// winner under this exact key (shape × pattern family label ×
    /// sparsity × thread budget).
    pub fn tile_config(
        &self,
        shape: GemmShape,
        pattern: &str,
        sparsity: f64,
        nthreads: usize,
    ) -> Option<TileConfig> {
        self.get(&PlanKey::new(shape, pattern, sparsity, nthreads)).map(TunedEntry::tile)
    }

    /// Serving-time resolution: the best tuned tile config for a GEMM
    /// whose exact key may not be in the cache.  (K, N, pattern) must
    /// match exactly — those determine the operand layout — while tile
    /// decisions transfer across the batch dimension M (GEMM cost is
    /// linear in M; the tuner itself caps M when tuning) and across
    /// nearby sparsities (the tuner keys DENSE at sparsity 0 regardless
    /// of the workload's pruning target).  Prefers the entry nearest in
    /// sparsity, then nearest in M, then the smallest thread budget.
    /// The native serving backend resolves every packed layer's
    /// [`TileConfig`] through this at load time.
    pub fn lookup_tile_config(
        &self,
        shape: GemmShape,
        pattern: &str,
        sparsity: f64,
    ) -> Option<TileConfig> {
        let want_bp = (sparsity * 10_000.0).round().clamp(0.0, 10_000.0) as i64;
        self.entries
            .values()
            .filter(|e| e.key.k == shape.k && e.key.n == shape.n && e.key.pattern == pattern)
            .min_by_key(|e| {
                (
                    (e.key.sparsity_bp as i64 - want_bp).abs(),
                    (e.key.m as i64 - shape.m as i64).abs(),
                    e.key.nthreads,
                )
            })
            .map(TunedEntry::tile)
    }

    /// Serving-time precision resolution, the `Precision::Auto` seam:
    /// the tuned numeric precision for a GEMM under the same transfer
    /// rule as [`PlanCache::lookup_tile_config`] — exact (K, N, pattern),
    /// nearest sparsity, then nearest M, then smallest thread budget.
    /// `None` (untuned shape) means the packer stays at f32.
    pub fn lookup_precision(
        &self,
        shape: GemmShape,
        pattern: &str,
        sparsity: f64,
    ) -> Option<Precision> {
        let want_bp = (sparsity * 10_000.0).round().clamp(0.0, 10_000.0) as i64;
        self.entries
            .values()
            .filter(|e| e.key.k == shape.k && e.key.n == shape.n && e.key.pattern == pattern)
            .min_by_key(|e| {
                (
                    (e.key.sparsity_bp as i64 - want_bp).abs(),
                    (e.key.m as i64 - shape.m as i64).abs(),
                    e.key.nthreads,
                )
            })
            .map(TunedEntry::precision_value)
    }

    pub fn set_model_variant(&mut self, model: &str, variant: &str) {
        self.models.insert(model.to_string(), variant.to_string());
    }

    /// The tuned serving recommendation for a model-zoo entry.
    pub fn model_variant(&self, model: &str) -> Option<&str> {
        self.models.get(model).map(String::as_str)
    }

    pub fn model_names(&self) -> impl Iterator<Item = &String> {
        self.models.keys()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema_version", num(SCHEMA_VERSION as f64)),
            ("entries", arr(self.entries.values().map(TunedEntry::to_json).collect())),
            (
                "models",
                Json::Obj(
                    self.models.iter().map(|(k, v)| (k.clone(), s(v))).collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<PlanCache> {
        let version = v
            .get("schema_version")
            .and_then(Json::as_f64)
            .context("plan cache missing \"schema_version\"")? as u64;
        if version != SCHEMA_VERSION {
            bail!(
                "plan cache schema_version {version} != supported {SCHEMA_VERSION}; \
                 re-run `tilewise autotune` to regenerate"
            );
        }
        let mut cache = PlanCache::new();
        for e in v.get("entries").and_then(Json::as_arr).unwrap_or(&[]) {
            cache.insert(TunedEntry::from_json(e)?);
        }
        if let Some(models) = v.get("models").and_then(Json::as_obj) {
            for (name, variant) in models {
                if let Some(variant) = variant.as_str() {
                    cache.set_model_variant(name, variant);
                }
            }
        }
        Ok(cache)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing plan cache {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<PlanCache> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan cache {}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        PlanCache::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::space::PatternFamily;

    fn entry(m: usize, pattern: &str) -> TunedEntry {
        TunedEntry {
            key: PlanKey::new(GemmShape::new(m, 768, 3072), pattern, 0.75, 1),
            variant: "tw-fused".into(),
            bm: 64,
            bk: 64,
            g: 32,
            threads: 1,
            micro: "auto".into(),
            precision: "fp32".into(),
            measured_us: 100.0,
            model_us: 80.0,
            default_us: 150.0,
        }
    }

    #[test]
    fn roundtrip_through_json() {
        let mut cache = PlanCache::new();
        cache.insert(entry(256, "TW"));
        cache.insert(entry(256, "TVW"));
        cache.set_model_variant("bert", "model_tw");
        let text = cache.to_json().to_string();
        let back = PlanCache::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.model_variant("bert"), Some("model_tw"));
        let key = PlanKey::new(GemmShape::new(256, 768, 3072), "TW", 0.75, 1);
        let e = back.get(&key).expect("entry survives");
        assert_eq!(e.g, 32);
        assert_eq!(e.variant, "tw-fused");
        assert!((e.speedup() - 1.5).abs() < 1e-9);
        let cand = e.candidate().unwrap();
        assert_eq!(cand.variant.family(), PatternFamily::Tw);
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut cache = PlanCache::new();
        cache.insert(entry(64, "TW"));
        let text = cache
            .to_json()
            .to_string()
            .replace("\"schema_version\":3", "\"schema_version\":99");
        assert!(text.contains("99"), "fixture edit failed");
        let v = Json::parse(&text).unwrap();
        assert!(PlanCache::from_json(&v).is_err());
    }

    #[test]
    fn stale_or_invalid_entries_are_rejected_on_load() {
        // a cache written by a buggy or older tuner: structurally valid
        // JSON whose tuned parameters no kernel could honour.  Loading
        // must fail with a clear error instead of serving a zero-extent
        // or misaligned blocking.
        let mut cache = PlanCache::new();
        cache.insert(entry(64, "TW"));
        let good = cache.to_json().to_string();
        // bm = 0: block extents must be nonzero
        let v = Json::parse(&good.replace("\"bm\":64", "\"bm\":0")).unwrap();
        let err = PlanCache::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("must be nonzero"), "{err}");
        // a 2:4-family entry whose bk is not a K-group multiple
        let mut cache = PlanCache::new();
        let mut e = entry(64, "TVW");
        e.variant = "tvw".into();
        e.bk = 66;
        cache.insert(e);
        let v = Json::parse(&cache.to_json().to_string()).unwrap();
        let err = PlanCache::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("multiple of 4"), "{err}");
        // an unknown microkernel label
        let v = Json::parse(&good.replace("\"micro\":\"auto\"", "\"micro\":\"simd9z\"")).unwrap();
        let err = PlanCache::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("microkernel"), "{err}");
        // an unknown precision label
        let v =
            Json::parse(&good.replace("\"precision\":\"fp32\"", "\"precision\":\"fp64\"")).unwrap();
        let err = PlanCache::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("precision"), "{err}");
        // the unedited cache still loads, micro intact
        let back = PlanCache::from_json(&Json::parse(&good).unwrap()).unwrap();
        assert_eq!(back.entries().next().unwrap().micro_cfg(), MicroCfg::Auto);
    }

    #[test]
    fn tuned_micro_rides_through_tile_lookups() {
        let mut cache = PlanCache::new();
        let mut e = entry(256, "TW");
        e.micro = "simd4x16".into();
        cache.insert(e);
        let shape = GemmShape::new(256, 768, 3072);
        let want = MicroCfg::Simd { mr: 4, nr: 16 };
        assert_eq!(cache.tile_config(shape, "TW", 0.75, 1).unwrap().micro, want);
        let far = GemmShape::new(1024, 768, 3072);
        assert_eq!(cache.lookup_tile_config(far, "TW", 0.8).unwrap().micro, want);
        // and JSON round-trips it
        let back = PlanCache::from_json(&Json::parse(&cache.to_json().to_string()).unwrap());
        assert_eq!(back.unwrap().entries().next().unwrap().micro, "simd4x16");
    }

    #[test]
    fn precision_persists_and_resolves_for_serving() {
        let mut cache = PlanCache::new();
        let mut e = entry(256, "DENSE");
        e.precision = "int8".into();
        cache.insert(e);
        // round-trips through JSON
        let back = PlanCache::from_json(&Json::parse(&cache.to_json().to_string()).unwrap());
        let back = back.unwrap();
        assert_eq!(back.entries().next().unwrap().precision_value(), Precision::Int8);
        // transfers across M like tile lookups (the quantize-at-pack seam)
        let serving = GemmShape::new(1024, 768, 3072);
        assert_eq!(back.lookup_precision(serving, "DENSE", 0.75), Some(Precision::Int8));
        assert_eq!(back.lookup_precision(serving, "TW", 0.75), None);
        // a missing precision key defaults to fp32 (freshly bumped caches)
        let text = cache.to_json().to_string().replace("\"precision\":\"int8\",", "");
        let back = PlanCache::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.entries().next().unwrap().precision_value(), Precision::Fp32);
    }

    #[test]
    fn save_and_load_file() {
        let dir = std::env::temp_dir().join(format!("tilewise_cache_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plans.json");
        let mut cache = PlanCache::new();
        cache.insert(entry(128, "TW"));
        cache.set_model_variant("bert", "model_tw");
        cache.save(&path).unwrap();
        let back = PlanCache::load(&path).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back.model_variant("bert"), Some("model_tw"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_file_errors() {
        assert!(PlanCache::load(Path::new("/no/such/plan/cache.json")).is_err());
    }

    #[test]
    fn tile_config_resolves_or_misses() {
        let mut cache = PlanCache::new();
        cache.insert(entry(256, "TW"));
        let shape = GemmShape::new(256, 768, 3072);
        assert_eq!(cache.tile_config(shape, "TW", 0.75, 1), Some(TileConfig::new(64, 64)));
        assert_eq!(cache.tile_config(shape, "TVW", 0.75, 1), None);
        assert_eq!(cache.tile_config(GemmShape::new(1, 2, 3), "TW", 0.75, 1), None);
    }

    #[test]
    fn lookup_transfers_across_m_sparsity_and_threads() {
        let mut cache = PlanCache::new();
        // DENSE keyed at sparsity 0 (the tuner's convention) and capped M
        let mut dense = entry(256, "DENSE");
        dense.key.sparsity_bp = 0;
        dense.key.nthreads = 8;
        dense.bm = 128;
        cache.insert(dense);
        cache.insert(entry(256, "TW"));
        // serving shape: larger M, pruned-workload sparsity, serial worker
        let serving = GemmShape::new(1024, 768, 3072);
        assert_eq!(
            cache.lookup_tile_config(serving, "DENSE", 0.75),
            Some(TileConfig::new(128, 64))
        );
        assert_eq!(cache.lookup_tile_config(serving, "TW", 0.75), Some(TileConfig::new(64, 64)));
        // (K, N, pattern) must match exactly
        assert_eq!(cache.lookup_tile_config(GemmShape::new(1024, 768, 3073), "TW", 0.75), None);
        assert_eq!(cache.lookup_tile_config(serving, "TVW", 0.75), None);
        // nearest sparsity wins when several entries share (K, N, pattern)
        let mut near = entry(256, "TW");
        near.key.sparsity_bp = 9000;
        near.bm = 16;
        cache.insert(near);
        assert_eq!(cache.lookup_tile_config(serving, "TW", 0.88), Some(TileConfig::new(16, 64)));
        assert_eq!(cache.lookup_tile_config(serving, "TW", 0.75), Some(TileConfig::new(64, 64)));
    }

    #[test]
    fn key_basis_points_are_stable() {
        let k1 = PlanKey::new(GemmShape::new(1, 2, 3), "TW", 0.75, 2);
        let k2 = PlanKey::new(GemmShape::new(1, 2, 3), "TW", 0.7500001, 2);
        assert_eq!(k1.id(), k2.id());
    }
}
