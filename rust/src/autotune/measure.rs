//! Wall-clock microbenchmark harness: warmup + time-budgeted sampling with
//! a trimmed mean, plus the operand factory that turns a (shape, sparsity)
//! tuning problem into real pruned matrices and condensed plans.
//!
//! Parallel candidates are measured on the persistent [`crate::pool`]
//! runtime (the same pool the serving stack's kernels claim chunks from),
//! so a tuned `threads` axis reflects pool-dispatch reality rather than
//! per-call spawn costs.  Candidates whose kernel would silently fall
//! back to serial at the measured shape are rejected outright — the cache
//! must never credit phantom parallelism.

use std::collections::HashMap;
use std::rc::Rc;

use super::space::{Candidate, KernelVariant};
use crate::gemm::{
    effective_parallel_threads, matmul_parallel, matmul_tiled, tvw_effective_parallel_threads,
    tvw_matmul_parallel_into, tvw_matmul_with, tw_effective_parallel_threads, tw_matmul_parallel,
    tw_matmul_with, vw24_effective_parallel_threads, vw24_matmul_parallel_into, vw24_matmul_with,
};
use crate::gpusim::GemmShape;
use crate::sparse::{prune_tvw, prune_tw, prune_vw, TvwPlan, TwPlan, Vw24Plan};
use crate::tensor::Matrix;
use crate::util::{Rng, Stopwatch};

/// Sampling policy for one measurement.
#[derive(Clone, Debug)]
pub struct MeasureOpts {
    /// Unrecorded runs before sampling starts.
    pub warmup: usize,
    /// Always collect at least this many samples.
    pub min_iters: usize,
    /// Never collect more than this many.
    pub max_iters: usize,
    /// Stop sampling once this much wall-clock has been spent.
    pub budget_secs: f64,
    /// Fraction trimmed from *each* end before averaging (outlier guard).
    pub trim_frac: f64,
}

impl Default for MeasureOpts {
    fn default() -> Self {
        MeasureOpts {
            warmup: 1,
            min_iters: 3,
            max_iters: 50,
            budget_secs: 0.12,
            trim_frac: 0.2,
        }
    }
}

impl MeasureOpts {
    /// A faster profile for benches / CI-adjacent runs.
    pub fn quick() -> MeasureOpts {
        MeasureOpts { warmup: 1, min_iters: 2, max_iters: 20, budget_secs: 0.05, trim_frac: 0.25 }
    }
}

/// One measurement result.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Trimmed-mean latency, seconds.
    pub mean_secs: f64,
    /// Fastest observed sample, seconds.
    pub min_secs: f64,
    /// Samples taken (after warmup).
    pub iters: usize,
}

/// Run `f` under the sampling policy and summarise.
pub fn measure<F: FnMut()>(mut f: F, opts: &MeasureOpts) -> Measurement {
    for _ in 0..opts.warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let clock = Stopwatch::start();
    while samples.len() < opts.min_iters.max(1)
        || (clock.secs() < opts.budget_secs && samples.len() < opts.max_iters.max(1))
    {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.secs());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = samples.len();
    let trim = ((n as f64) * opts.trim_frac.clamp(0.0, 0.49)).floor() as usize;
    let kept = &samples[trim..n - trim];
    let mean = kept.iter().sum::<f64>() / kept.len().max(1) as f64;
    Measurement { mean_secs: mean, min_secs: samples[0], iters: n }
}

/// Operands shared by every candidate of one (shape, sparsity) tuning run:
/// the activation and weight matrices plus lazily-encoded condensed plans,
/// cached per granularity so re-measuring a G costs nothing extra.
pub struct BenchData {
    pub shape: GemmShape,
    pub sparsity: f64,
    pub a: Matrix,
    pub w: Matrix,
    tw_plans: HashMap<usize, Rc<TwPlan>>,
    tvw_plans: HashMap<usize, Rc<TvwPlan>>,
    vw_plan: Option<Option<Rc<Vw24Plan>>>,
}

impl BenchData {
    pub fn new(shape: GemmShape, sparsity: f64, seed: u64) -> BenchData {
        let mut rng = Rng::new(seed);
        let a = Matrix::randn(shape.m, shape.k, &mut rng);
        let w = Matrix::randn(shape.k, shape.n, &mut rng);
        BenchData {
            shape,
            sparsity,
            a,
            w,
            tw_plans: HashMap::new(),
            tvw_plans: HashMap::new(),
            vw_plan: None,
        }
    }

    /// Condensed TW plan at granularity `g` (encoded once, then cached).
    pub fn tw_plan(&mut self, g: usize) -> Rc<TwPlan> {
        let (w, sparsity) = (&self.w, self.sparsity);
        self.tw_plans
            .entry(g)
            .or_insert_with(|| {
                let tw = prune_tw(w, sparsity, g, None);
                Rc::new(TwPlan::encode(w, &tw))
            })
            .clone()
    }

    /// Condensed TVW plan at granularity `g` (TVW needs >= 50% sparsity
    /// for the 2:4 leg, matching `Pattern::prune`).
    pub fn tvw_plan(&mut self, g: usize) -> Rc<TvwPlan> {
        let (w, sparsity) = (&self.w, self.sparsity.max(0.5));
        self.tvw_plans
            .entry(g)
            .or_insert_with(|| {
                let (tw, mask) = prune_tvw(w, sparsity, g);
                Rc::new(TvwPlan::encode(w, &tw, &mask))
            })
            .clone()
    }

    /// 2:4 plan (fixed 50% sparsity); `None` when K is not 4-aligned.
    pub fn vw24_plan(&mut self) -> Option<Rc<Vw24Plan>> {
        if self.vw_plan.is_none() {
            let built = if self.shape.k % 4 == 0 {
                let mask = prune_vw(&self.w, 0.5, 4);
                Vw24Plan::encode(&self.w, &mask).ok().map(Rc::new)
            } else {
                None
            };
            self.vw_plan = Some(built);
        }
        self.vw_plan.clone().unwrap()
    }
}

/// Measure one candidate end-to-end on `data`'s operands.  Returns `None`
/// when the candidate cannot run on this problem (e.g. 2:4 with K % 4 != 0).
pub fn bench_candidate(
    data: &mut BenchData,
    cand: &Candidate,
    opts: &MeasureOpts,
) -> Option<Measurement> {
    let tile = cand.tile;
    match cand.variant {
        KernelVariant::DenseBlocked => {
            let (a, w) = (&data.a, &data.w);
            Some(measure(
                || {
                    std::hint::black_box(matmul_tiled(a, w, &tile));
                },
                opts,
            ))
        }
        KernelVariant::DenseParallel => {
            let (a, w) = (&data.a, &data.w);
            let t = cand.threads.max(1);
            // phantom-parallelism guard: a candidate whose kernel would
            // run fewer threads than requested (serial fallback OR clamp)
            // must not be measured — the cache would credit `threads` the
            // kernel never used.  Each guard calls the kernel's own
            // effective-threads helper, the single source of truth.
            if t > 1 && effective_parallel_threads(data.shape.m, t) != t {
                return None;
            }
            Some(measure(
                || {
                    std::hint::black_box(matmul_parallel(a, w, t));
                },
                opts,
            ))
        }
        KernelVariant::TwFused => {
            let plan = data.tw_plan(cand.g.max(1));
            let a = &data.a;
            Some(measure(
                || {
                    std::hint::black_box(tw_matmul_with(a, &plan, &tile));
                },
                opts,
            ))
        }
        KernelVariant::TwParallel => {
            let plan = data.tw_plan(cand.g.max(1));
            let a = &data.a;
            let t = cand.threads.max(1);
            if t > 1 && tw_effective_parallel_threads(plan.tiles, t) != t {
                return None; // phantom-parallelism guard (see DenseParallel)
            }
            Some(measure(
                || {
                    std::hint::black_box(tw_matmul_parallel(a, &plan, t));
                },
                opts,
            ))
        }
        KernelVariant::TvwFused => {
            let plan = data.tvw_plan(cand.g.max(1));
            let a = &data.a;
            Some(measure(
                || {
                    std::hint::black_box(tvw_matmul_with(a, &plan, &tile));
                },
                opts,
            ))
        }
        KernelVariant::TvwParallel => {
            let plan = data.tvw_plan(cand.g.max(1));
            let a = &data.a;
            let t = cand.threads.max(1);
            if t > 1 && tvw_effective_parallel_threads(plan.tiles, t) != t {
                return None; // phantom-parallelism guard (see DenseParallel)
            }
            // measured on the same persistent pool the serving stack uses,
            // with the output allocation reused across samples (the
            // serving hot-loop idiom)
            let mut c = Matrix::zeros(a.rows, plan.n);
            Some(measure(
                || {
                    tvw_matmul_parallel_into(a, &plan, &mut c, &tile, t, crate::pool::global());
                    std::hint::black_box(&c);
                },
                opts,
            ))
        }
        KernelVariant::Vw24 => {
            let plan = data.vw24_plan()?;
            let a = &data.a;
            Some(measure(
                || {
                    std::hint::black_box(vw24_matmul_with(a, &plan, &tile));
                },
                opts,
            ))
        }
        KernelVariant::Vw24Parallel => {
            let plan = data.vw24_plan()?;
            let a = &data.a;
            let t = cand.threads.max(1);
            if t > 1 && vw24_effective_parallel_threads(plan.n, t) != t {
                return None; // phantom-parallelism guard (see DenseParallel)
            }
            let mut c = Matrix::zeros(a.rows, plan.n);
            Some(measure(
                || {
                    vw24_matmul_parallel_into(a, &plan, &mut c, &tile, t, crate::pool::global());
                    std::hint::black_box(&c);
                },
                opts,
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::space::PatternFamily;

    #[test]
    fn measure_counts_and_orders() {
        let mut calls = 0usize;
        let opts = MeasureOpts { warmup: 2, min_iters: 3, max_iters: 5, budget_secs: 0.0, trim_frac: 0.2 };
        let m = measure(
            || {
                calls += 1;
                std::hint::black_box((0..500).sum::<usize>());
            },
            &opts,
        );
        assert_eq!(m.iters, 3);
        assert_eq!(calls, 2 + 3);
        assert!(m.min_secs <= m.mean_secs * 1.0001);
        assert!(m.mean_secs >= 0.0);
    }

    #[test]
    fn bench_data_caches_plans() {
        let mut data = BenchData::new(GemmShape::new(16, 64, 48), 0.75, 7);
        let p1 = data.tw_plan(16);
        let p2 = data.tw_plan(16);
        assert!(Rc::ptr_eq(&p1, &p2));
        assert_eq!(p1.g, 16);
        assert!(data.vw24_plan().is_some());
    }

    #[test]
    fn every_family_default_is_measurable() {
        let mut data = BenchData::new(GemmShape::new(8, 32, 32), 0.5, 9);
        let opts = MeasureOpts { warmup: 0, min_iters: 1, max_iters: 1, budget_secs: 0.0, trim_frac: 0.0 };
        for family in
            [PatternFamily::Dense, PatternFamily::Tw, PatternFamily::Tvw, PatternFamily::Vw24]
        {
            let cand = Candidate::default_for(family);
            assert!(bench_candidate(&mut data, &cand, &opts).is_some(), "{family:?}");
        }
    }

    #[test]
    fn micro_axis_candidates_are_measurable() {
        use crate::gemm::MicroCfg;
        let mut data = BenchData::new(GemmShape::new(8, 32, 32), 0.5, 12);
        let opts =
            MeasureOpts { warmup: 0, min_iters: 1, max_iters: 1, budget_secs: 0.0, trim_frac: 0.0 };
        for mc in [MicroCfg::Scalar, MicroCfg::Simd { mr: 4, nr: 16 }] {
            for family in
                [PatternFamily::Dense, PatternFamily::Tw, PatternFamily::Tvw, PatternFamily::Vw24]
            {
                let mut cand = Candidate::default_for(family);
                cand.tile = cand.tile.with_micro(mc);
                assert!(bench_candidate(&mut data, &cand, &opts).is_some(), "{family:?} {mc:?}");
            }
        }
    }

    #[test]
    fn phantom_parallel_candidates_are_rejected() {
        use crate::gemm::TileConfig;
        // M = 8 is far below the 8-rows-per-band floor for 4 threads: the
        // kernel would run serial, so the candidate must not be measured
        let mut data = BenchData::new(GemmShape::new(8, 64, 48), 0.75, 11);
        let opts = MeasureOpts::quick();
        let dense_par = Candidate {
            variant: KernelVariant::DenseParallel,
            tile: TileConfig::dense_default(),
            g: 0,
            threads: 4,
        };
        assert!(bench_candidate(&mut data, &dense_par, &opts).is_none());
        // a genuinely parallelisable TVW plan (several condensed tiles)
        // stays measurable at the same tiny M
        let tvw_par = Candidate {
            variant: KernelVariant::TvwParallel,
            tile: TileConfig::tvw_default(),
            g: 16,
            threads: 2,
        };
        assert!(bench_candidate(&mut data, &tvw_par, &opts).is_some());
        // column-parallel 2:4 needs >= 16 columns per thread
        let vw_par = Candidate {
            variant: KernelVariant::Vw24Parallel,
            tile: TileConfig::vw_default(),
            g: 0,
            threads: 32,
        };
        assert!(bench_candidate(&mut data, &vw_par, &opts).is_none());
    }

    #[test]
    fn vw24_unalignable_k_is_rejected() {
        let mut data = BenchData::new(GemmShape::new(8, 30, 32), 0.5, 10);
        let cand = Candidate::default_for(PatternFamily::Vw24);
        let opts = MeasureOpts::quick();
        assert!(bench_candidate(&mut data, &cand, &opts).is_none());
    }
}
