//! Wall-clock microbenchmark harness: warmup + time-budgeted sampling with
//! a trimmed mean, plus the operand factory that turns a (shape, sparsity)
//! tuning problem into real pruned matrices and condensed plans.
//!
//! Parallel candidates are measured on the persistent [`crate::pool`]
//! runtime (the same pool the serving stack's kernels claim chunks from),
//! so a tuned `threads` axis reflects pool-dispatch reality rather than
//! per-call spawn costs.  Candidates whose kernel would silently fall
//! back to serial at the measured shape are rejected outright — the cache
//! must never credit phantom parallelism.

use std::collections::HashMap;
use std::rc::Rc;

use super::space::{Candidate, KernelVariant};
use crate::gemm::{
    effective_parallel_threads, int8_dense_panel, int8_matmul_parallel_into,
    int8_matmul_tiled_into, int8_tvw_matmul_into, int8_tw_matmul_into, int8_tw_pack_panels,
    int8_vw24_matmul_into, matmul_parallel, matmul_tiled, micro, tvw_effective_parallel_threads,
    tvw_matmul_parallel_into, tvw_matmul_with, tw_effective_parallel_threads, tw_matmul_parallel,
    tw_matmul_with, vw24_effective_parallel_threads, vw24_matmul_parallel_into, vw24_matmul_with,
    GemmScratch, Int8TvwPlan, Int8TwPlan, Int8Vw24Plan,
};
use crate::gpusim::GemmShape;
use crate::quant::{Precision, QuantMatrix};
use crate::sparse::{prune_tvw, prune_tw, prune_vw, TvwPlan, TwPlan, Vw24Plan};
use crate::tensor::Matrix;
use crate::util::{Rng, Stopwatch};

/// Sampling policy for one measurement.
#[derive(Clone, Debug)]
pub struct MeasureOpts {
    /// Unrecorded runs before sampling starts.
    pub warmup: usize,
    /// Always collect at least this many samples.
    pub min_iters: usize,
    /// Never collect more than this many.
    pub max_iters: usize,
    /// Stop sampling once this much wall-clock has been spent.
    pub budget_secs: f64,
    /// Fraction trimmed from *each* end before averaging (outlier guard).
    pub trim_frac: f64,
}

impl Default for MeasureOpts {
    fn default() -> Self {
        MeasureOpts {
            warmup: 1,
            min_iters: 3,
            max_iters: 50,
            budget_secs: 0.12,
            trim_frac: 0.2,
        }
    }
}

impl MeasureOpts {
    /// A faster profile for benches / CI-adjacent runs.
    pub fn quick() -> MeasureOpts {
        MeasureOpts { warmup: 1, min_iters: 2, max_iters: 20, budget_secs: 0.05, trim_frac: 0.25 }
    }
}

/// One measurement result.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Trimmed-mean latency, seconds.
    pub mean_secs: f64,
    /// Fastest observed sample, seconds.
    pub min_secs: f64,
    /// Samples taken (after warmup).
    pub iters: usize,
}

/// Run `f` under the sampling policy and summarise.
pub fn measure<F: FnMut()>(mut f: F, opts: &MeasureOpts) -> Measurement {
    for _ in 0..opts.warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let clock = Stopwatch::start();
    while samples.len() < opts.min_iters.max(1)
        || (clock.secs() < opts.budget_secs && samples.len() < opts.max_iters.max(1))
    {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.secs());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = samples.len();
    let trim = ((n as f64) * opts.trim_frac.clamp(0.0, 0.49)).floor() as usize;
    let kept = &samples[trim..n - trim];
    let mean = kept.iter().sum::<f64>() / kept.len().max(1) as f64;
    Measurement { mean_secs: mean, min_secs: samples[0], iters: n }
}

/// Operands shared by every candidate of one (shape, sparsity) tuning run:
/// the activation and weight matrices plus lazily-encoded condensed plans,
/// cached per granularity so re-measuring a G costs nothing extra.
pub struct BenchData {
    pub shape: GemmShape,
    pub sparsity: f64,
    pub a: Matrix,
    pub w: Matrix,
    tw_plans: HashMap<usize, Rc<TwPlan>>,
    tvw_plans: HashMap<usize, Rc<TvwPlan>>,
    vw_plan: Option<Option<Rc<Vw24Plan>>>,
    // quantized twins, converted from the f32 plans above on demand
    qw: Option<Rc<QuantMatrix>>,
    int8_tw_plans: HashMap<usize, Rc<Int8TwPlan>>,
    int8_tvw_plans: HashMap<usize, Rc<Int8TvwPlan>>,
    int8_vw_plan: Option<Option<Rc<Int8Vw24Plan>>>,
}

impl BenchData {
    pub fn new(shape: GemmShape, sparsity: f64, seed: u64) -> BenchData {
        let mut rng = Rng::new(seed);
        let a = Matrix::randn(shape.m, shape.k, &mut rng);
        let w = Matrix::randn(shape.k, shape.n, &mut rng);
        BenchData {
            shape,
            sparsity,
            a,
            w,
            tw_plans: HashMap::new(),
            tvw_plans: HashMap::new(),
            vw_plan: None,
            qw: None,
            int8_tw_plans: HashMap::new(),
            int8_tvw_plans: HashMap::new(),
            int8_vw_plan: None,
        }
    }

    /// Condensed TW plan at granularity `g` (encoded once, then cached).
    pub fn tw_plan(&mut self, g: usize) -> Rc<TwPlan> {
        let (w, sparsity) = (&self.w, self.sparsity);
        self.tw_plans
            .entry(g)
            .or_insert_with(|| {
                let tw = prune_tw(w, sparsity, g, None);
                Rc::new(TwPlan::encode(w, &tw))
            })
            .clone()
    }

    /// Condensed TVW plan at granularity `g` (TVW needs >= 50% sparsity
    /// for the 2:4 leg, matching `Pattern::prune`).
    pub fn tvw_plan(&mut self, g: usize) -> Rc<TvwPlan> {
        let (w, sparsity) = (&self.w, self.sparsity.max(0.5));
        self.tvw_plans
            .entry(g)
            .or_insert_with(|| {
                let (tw, mask) = prune_tvw(w, sparsity, g);
                Rc::new(TvwPlan::encode(w, &tw, &mask))
            })
            .clone()
    }

    /// 2:4 plan (fixed 50% sparsity); `None` when K is not 4-aligned.
    pub fn vw24_plan(&mut self) -> Option<Rc<Vw24Plan>> {
        if self.vw_plan.is_none() {
            let built = if self.shape.k % 4 == 0 {
                let mask = prune_vw(&self.w, 0.5, 4);
                Vw24Plan::encode(&self.w, &mask).ok().map(Rc::new)
            } else {
                None
            };
            self.vw_plan = Some(built);
        }
        self.vw_plan.clone().unwrap()
    }

    /// Per-channel quantized weight (built once from `w`).
    pub fn quant_weight(&mut self) -> Rc<QuantMatrix> {
        let w = &self.w;
        self.qw.get_or_insert_with(|| Rc::new(QuantMatrix::quantize(w))).clone()
    }

    /// Quantized TW plan at granularity `g`, converted from the f32 plan
    /// so an int8 candidate is measured on the *same* pruning decision
    /// its f32 sibling was.
    pub fn int8_tw_plan(&mut self, g: usize) -> Rc<Int8TwPlan> {
        if !self.int8_tw_plans.contains_key(&g) {
            let plan = self.tw_plan(g);
            self.int8_tw_plans.insert(g, Rc::new(Int8TwPlan::from_plan(&plan)));
        }
        self.int8_tw_plans[&g].clone()
    }

    /// Quantized TVW plan at granularity `g` (same sparsity floor as
    /// [`BenchData::tvw_plan`]).
    pub fn int8_tvw_plan(&mut self, g: usize) -> Rc<Int8TvwPlan> {
        if !self.int8_tvw_plans.contains_key(&g) {
            let plan = self.tvw_plan(g);
            self.int8_tvw_plans.insert(g, Rc::new(Int8TvwPlan::from_plan(&plan)));
        }
        self.int8_tvw_plans[&g].clone()
    }

    /// Quantized 2:4 plan; `None` when K is not 4-aligned.
    pub fn int8_vw24_plan(&mut self) -> Option<Rc<Int8Vw24Plan>> {
        if self.int8_vw_plan.is_none() {
            let built = self.vw24_plan().map(|p| Rc::new(Int8Vw24Plan::from_plan(&p)));
            self.int8_vw_plan = Some(built);
        }
        self.int8_vw_plan.clone().unwrap()
    }
}

/// Measure one candidate end-to-end on `data`'s operands.  Returns `None`
/// when the candidate cannot run on this problem (e.g. 2:4 with K % 4 != 0).
pub fn bench_candidate(
    data: &mut BenchData,
    cand: &Candidate,
    opts: &MeasureOpts,
) -> Option<Measurement> {
    let tile = cand.tile;
    if cand.precision == Precision::Int8 {
        return bench_int8(data, cand, opts);
    }
    match cand.variant {
        KernelVariant::DenseBlocked => {
            let (a, w) = (&data.a, &data.w);
            Some(measure(
                || {
                    std::hint::black_box(matmul_tiled(a, w, &tile));
                },
                opts,
            ))
        }
        KernelVariant::DenseParallel => {
            let (a, w) = (&data.a, &data.w);
            let t = cand.threads.max(1);
            // phantom-parallelism guard: a candidate whose kernel would
            // run fewer threads than requested (serial fallback OR clamp)
            // must not be measured — the cache would credit `threads` the
            // kernel never used.  Each guard calls the kernel's own
            // effective-threads helper, the single source of truth.
            if t > 1 && effective_parallel_threads(data.shape.m, t) != t {
                return None;
            }
            Some(measure(
                || {
                    std::hint::black_box(matmul_parallel(a, w, t));
                },
                opts,
            ))
        }
        KernelVariant::TwFused => {
            let plan = data.tw_plan(cand.g.max(1));
            let a = &data.a;
            Some(measure(
                || {
                    std::hint::black_box(tw_matmul_with(a, &plan, &tile));
                },
                opts,
            ))
        }
        KernelVariant::TwParallel => {
            let plan = data.tw_plan(cand.g.max(1));
            let a = &data.a;
            let t = cand.threads.max(1);
            if t > 1 && tw_effective_parallel_threads(plan.tiles, t) != t {
                return None; // phantom-parallelism guard (see DenseParallel)
            }
            Some(measure(
                || {
                    std::hint::black_box(tw_matmul_parallel(a, &plan, t));
                },
                opts,
            ))
        }
        KernelVariant::TvwFused => {
            let plan = data.tvw_plan(cand.g.max(1));
            let a = &data.a;
            Some(measure(
                || {
                    std::hint::black_box(tvw_matmul_with(a, &plan, &tile));
                },
                opts,
            ))
        }
        KernelVariant::TvwParallel => {
            let plan = data.tvw_plan(cand.g.max(1));
            let a = &data.a;
            let t = cand.threads.max(1);
            if t > 1 && tvw_effective_parallel_threads(plan.tiles, t) != t {
                return None; // phantom-parallelism guard (see DenseParallel)
            }
            // measured on the same persistent pool the serving stack uses,
            // with the output allocation reused across samples (the
            // serving hot-loop idiom)
            let mut c = Matrix::zeros(a.rows, plan.n);
            Some(measure(
                || {
                    tvw_matmul_parallel_into(a, &plan, &mut c, &tile, t, crate::pool::global());
                    std::hint::black_box(&c);
                },
                opts,
            ))
        }
        KernelVariant::Vw24 => {
            let plan = data.vw24_plan()?;
            let a = &data.a;
            Some(measure(
                || {
                    std::hint::black_box(vw24_matmul_with(a, &plan, &tile));
                },
                opts,
            ))
        }
        KernelVariant::Vw24Parallel => {
            let plan = data.vw24_plan()?;
            let a = &data.a;
            let t = cand.threads.max(1);
            if t > 1 && vw24_effective_parallel_threads(plan.n, t) != t {
                return None; // phantom-parallelism guard (see DenseParallel)
            }
            let mut c = Matrix::zeros(a.rows, plan.n);
            Some(measure(
                || {
                    vw24_matmul_parallel_into(a, &plan, &mut c, &tile, t, crate::pool::global());
                    std::hint::black_box(&c);
                },
                opts,
            ))
        }
    }
}

/// Int8 leg of [`bench_candidate`]: the same variants, run through the
/// i8×i8→i32 kernels with packed-i8 panels and a reused [`GemmScratch`]
/// (the serving hot-loop idiom — dynamic activation quantization is part
/// of the measured cost, exactly as it is per dispatch at serve time).
/// Only dense has a pooled int8 entry point, so int8 × parallel condensed
/// variants are unmeasurable and return `None` (the search space already
/// skips them; this keeps ad-hoc candidates honest too).
fn bench_int8(data: &mut BenchData, cand: &Candidate, opts: &MeasureOpts) -> Option<Measurement> {
    let tile = cand.tile;
    let nr = micro::resolve(&tile).nr;
    let mut scratch = GemmScratch::new();
    match cand.variant {
        KernelVariant::DenseBlocked => {
            let qw = data.quant_weight();
            let panel = int8_dense_panel(&qw, nr);
            let a = &data.a;
            let mut c = Matrix::zeros(a.rows, qw.cols);
            Some(measure(
                || {
                    int8_matmul_tiled_into(a, &qw, Some(&panel), &mut c, &tile, &mut scratch);
                    std::hint::black_box(&c);
                },
                opts,
            ))
        }
        KernelVariant::DenseParallel => {
            let t = cand.threads.max(1);
            if t > 1 && effective_parallel_threads(data.shape.m, t) != t {
                return None; // phantom-parallelism guard (see bench_candidate)
            }
            let qw = data.quant_weight();
            let panel = int8_dense_panel(&qw, nr);
            let a = &data.a;
            let mut c = Matrix::zeros(a.rows, qw.cols);
            Some(measure(
                || {
                    int8_matmul_parallel_into(
                        a,
                        &qw,
                        Some(&panel),
                        &mut c,
                        &tile,
                        t,
                        crate::pool::global(),
                        &mut scratch,
                    );
                    std::hint::black_box(&c);
                },
                opts,
            ))
        }
        KernelVariant::TwFused => {
            let plan = data.int8_tw_plan(cand.g.max(1));
            let panels = int8_tw_pack_panels(&plan, nr);
            let a = &data.a;
            // the TW scatter assigns kept columns; dropped columns stay at
            // the zero this allocation starts from
            let mut c = Matrix::zeros(a.rows, plan.n);
            Some(measure(
                || {
                    int8_tw_matmul_into(a, &plan, Some(&panels), &mut c, &tile, &mut scratch);
                    std::hint::black_box(&c);
                },
                opts,
            ))
        }
        KernelVariant::TvwFused => {
            let plan = data.int8_tvw_plan(cand.g.max(1));
            let a = &data.a;
            let mut c = Matrix::zeros(a.rows, plan.n);
            Some(measure(
                || {
                    int8_tvw_matmul_into(a, &plan, &mut c, &tile, &mut scratch);
                    std::hint::black_box(&c);
                },
                opts,
            ))
        }
        KernelVariant::Vw24 => {
            let plan = data.int8_vw24_plan()?;
            let a = &data.a;
            let mut c = Matrix::zeros(a.rows, plan.n);
            Some(measure(
                || {
                    int8_vw24_matmul_into(a, &plan, &mut c, &tile, &mut scratch);
                    std::hint::black_box(&c);
                },
                opts,
            ))
        }
        KernelVariant::TwParallel | KernelVariant::TvwParallel | KernelVariant::Vw24Parallel => {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::space::PatternFamily;

    #[test]
    fn measure_counts_and_orders() {
        let mut calls = 0usize;
        let opts = MeasureOpts { warmup: 2, min_iters: 3, max_iters: 5, budget_secs: 0.0, trim_frac: 0.2 };
        let m = measure(
            || {
                calls += 1;
                std::hint::black_box((0..500).sum::<usize>());
            },
            &opts,
        );
        assert_eq!(m.iters, 3);
        assert_eq!(calls, 2 + 3);
        assert!(m.min_secs <= m.mean_secs * 1.0001);
        assert!(m.mean_secs >= 0.0);
    }

    #[test]
    fn bench_data_caches_plans() {
        let mut data = BenchData::new(GemmShape::new(16, 64, 48), 0.75, 7);
        let p1 = data.tw_plan(16);
        let p2 = data.tw_plan(16);
        assert!(Rc::ptr_eq(&p1, &p2));
        assert_eq!(p1.g, 16);
        assert!(data.vw24_plan().is_some());
    }

    #[test]
    fn every_family_default_is_measurable() {
        let mut data = BenchData::new(GemmShape::new(8, 32, 32), 0.5, 9);
        let opts = MeasureOpts { warmup: 0, min_iters: 1, max_iters: 1, budget_secs: 0.0, trim_frac: 0.0 };
        for family in
            [PatternFamily::Dense, PatternFamily::Tw, PatternFamily::Tvw, PatternFamily::Vw24]
        {
            let cand = Candidate::default_for(family);
            assert!(bench_candidate(&mut data, &cand, &opts).is_some(), "{family:?}");
        }
    }

    #[test]
    fn micro_axis_candidates_are_measurable() {
        use crate::gemm::MicroCfg;
        let mut data = BenchData::new(GemmShape::new(8, 32, 32), 0.5, 12);
        let opts =
            MeasureOpts { warmup: 0, min_iters: 1, max_iters: 1, budget_secs: 0.0, trim_frac: 0.0 };
        for mc in [MicroCfg::Scalar, MicroCfg::Simd { mr: 4, nr: 16 }] {
            for family in
                [PatternFamily::Dense, PatternFamily::Tw, PatternFamily::Tvw, PatternFamily::Vw24]
            {
                let mut cand = Candidate::default_for(family);
                cand.tile = cand.tile.with_micro(mc);
                assert!(bench_candidate(&mut data, &cand, &opts).is_some(), "{family:?} {mc:?}");
            }
        }
    }

    #[test]
    fn phantom_parallel_candidates_are_rejected() {
        use crate::gemm::TileConfig;
        // M = 8 is far below the 8-rows-per-band floor for 4 threads: the
        // kernel would run serial, so the candidate must not be measured
        let mut data = BenchData::new(GemmShape::new(8, 64, 48), 0.75, 11);
        let opts = MeasureOpts::quick();
        let dense_par = Candidate {
            variant: KernelVariant::DenseParallel,
            tile: TileConfig::dense_default(),
            g: 0,
            threads: 4,
            precision: Precision::Fp32,
        };
        assert!(bench_candidate(&mut data, &dense_par, &opts).is_none());
        // a genuinely parallelisable TVW plan (several condensed tiles)
        // stays measurable at the same tiny M
        let tvw_par = Candidate {
            variant: KernelVariant::TvwParallel,
            tile: TileConfig::tvw_default(),
            g: 16,
            threads: 2,
            precision: Precision::Fp32,
        };
        assert!(bench_candidate(&mut data, &tvw_par, &opts).is_some());
        // column-parallel 2:4 needs >= 16 columns per thread
        let vw_par = Candidate {
            variant: KernelVariant::Vw24Parallel,
            tile: TileConfig::vw_default(),
            g: 0,
            threads: 32,
            precision: Precision::Fp32,
        };
        assert!(bench_candidate(&mut data, &vw_par, &opts).is_none());
    }

    #[test]
    fn int8_candidates_are_measurable_per_family() {
        // K = 32 divides 4 (2:4 leg) and sits far below the i32
        // accumulator bound, so every family's int8 twin must measure
        let mut data = BenchData::new(GemmShape::new(8, 32, 32), 0.5, 21);
        let opts =
            MeasureOpts { warmup: 0, min_iters: 1, max_iters: 1, budget_secs: 0.0, trim_frac: 0.0 };
        for family in
            [PatternFamily::Dense, PatternFamily::Tw, PatternFamily::Tvw, PatternFamily::Vw24]
        {
            let mut cand = Candidate::default_for(family);
            cand.precision = Precision::Int8;
            assert!(bench_candidate(&mut data, &cand, &opts).is_some(), "{family:?} int8");
        }
        // quantized plans are cached like their f32 twins
        let q1 = data.quant_weight();
        let q2 = data.quant_weight();
        assert!(Rc::ptr_eq(&q1, &q2));
    }

    #[test]
    fn int8_parallel_condensed_is_rejected() {
        use crate::gemm::TileConfig;
        // plenty of condensed tiles — the f32 TW parallel kernel WOULD
        // run here, but there is no int8 pooled TW entry point, so the
        // int8 twin must be unmeasurable rather than silently mis-timed
        let mut data = BenchData::new(GemmShape::new(64, 64, 64), 0.75, 23);
        let opts = MeasureOpts::quick();
        let tw_par = Candidate {
            variant: KernelVariant::TwParallel,
            tile: TileConfig::tw_default(),
            g: 16,
            threads: 2,
            precision: Precision::Int8,
        };
        assert!(bench_candidate(&mut data, &tw_par, &opts).is_none());
        // ...while the int8 *dense* pooled kernel exists and measures
        let dense_par = Candidate {
            variant: KernelVariant::DenseParallel,
            tile: TileConfig::dense_default(),
            g: 0,
            threads: 2,
            precision: Precision::Int8,
        };
        assert!(bench_candidate(&mut data, &dense_par, &opts).is_some());
    }

    #[test]
    fn vw24_unalignable_k_is_rejected() {
        let mut data = BenchData::new(GemmShape::new(8, 30, 32), 0.5, 10);
        let cand = Candidate::default_for(PatternFamily::Vw24);
        let opts = MeasureOpts::quick();
        assert!(bench_candidate(&mut data, &cand, &opts).is_none());
    }
}
