//! Candidate enumeration: the discrete search space the tuner walks.
//!
//! A candidate is one executable strategy for a GEMM: which CPU kernel
//! runs it, its cache-blocking [`TileConfig`], the TW tile granularity G
//! (for condensed-plan kernels, where G is chosen at *encode* time), and
//! the worker thread count.

use crate::gemm::{micro, MicroCfg, TileConfig};
use crate::gpusim::GemmShape;
use crate::quant::Precision;

/// What the tuner optimises: the dense baseline or one sparsity-pattern
/// execution family.  (The pattern's G is a *search axis*, not part of
/// the family — `TW` covers TW-8 … TW-128.)
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PatternFamily {
    Dense,
    Tw,
    Tvw,
    Vw24,
}

impl PatternFamily {
    pub fn label(&self) -> &'static str {
        match self {
            PatternFamily::Dense => "DENSE",
            PatternFamily::Tw => "TW",
            PatternFamily::Tvw => "TVW",
            PatternFamily::Vw24 => "VW-4",
        }
    }

    pub fn from_label(s: &str) -> Option<PatternFamily> {
        Some(match s {
            "DENSE" => PatternFamily::Dense,
            "TW" => PatternFamily::Tw,
            "TVW" => PatternFamily::Tvw,
            "VW-4" => PatternFamily::Vw24,
            _ => return None,
        })
    }

    /// The serving-stack executable this family maps to (`meta.json`
    /// naming); `None` when no compiled variant exists for it.
    pub fn serving_variant(&self) -> Option<&'static str> {
        match self {
            PatternFamily::Dense => Some("model_dense"),
            PatternFamily::Tw => Some("model_tw"),
            PatternFamily::Tvw => Some("model_tvw"),
            PatternFamily::Vw24 => None,
        }
    }
}

/// Which CPU kernel executes the GEMM.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelVariant {
    /// `gemm::matmul_tiled` — cache-blocked dense.
    DenseBlocked,
    /// `gemm::matmul_parallel` — row-banded multi-threaded dense.
    DenseParallel,
    /// `gemm::tw_matmul_with` — single fused pass over all CTO tiles.
    TwFused,
    /// `gemm::tw_matmul_parallel` — tile-parallel CTO kernel.
    TwParallel,
    /// `gemm::tvw_matmul_with` — fused TW + 2:4 kernel.
    TvwFused,
    /// `gemm::tvw_matmul_parallel_into` — tile-parallel TVW kernel.
    TvwParallel,
    /// `gemm::vw24_matmul_with` — plain 2:4 kernel.
    Vw24,
    /// `gemm::vw24_matmul_parallel_into` — column-parallel 2:4 kernel.
    Vw24Parallel,
}

impl KernelVariant {
    pub fn label(&self) -> &'static str {
        match self {
            KernelVariant::DenseBlocked => "dense",
            KernelVariant::DenseParallel => "dense-par",
            KernelVariant::TwFused => "tw-fused",
            KernelVariant::TwParallel => "tw-par",
            KernelVariant::TvwFused => "tvw",
            KernelVariant::TvwParallel => "tvw-par",
            KernelVariant::Vw24 => "vw24",
            KernelVariant::Vw24Parallel => "vw24-par",
        }
    }

    pub fn from_label(s: &str) -> Option<KernelVariant> {
        Some(match s {
            "dense" => KernelVariant::DenseBlocked,
            "dense-par" => KernelVariant::DenseParallel,
            "tw-fused" => KernelVariant::TwFused,
            "tw-par" => KernelVariant::TwParallel,
            "tvw" => KernelVariant::TvwFused,
            "tvw-par" => KernelVariant::TvwParallel,
            "vw24" => KernelVariant::Vw24,
            "vw24-par" => KernelVariant::Vw24Parallel,
            _ => return None,
        })
    }

    pub fn is_parallel(&self) -> bool {
        matches!(
            self,
            KernelVariant::DenseParallel
                | KernelVariant::TwParallel
                | KernelVariant::TvwParallel
                | KernelVariant::Vw24Parallel
        )
    }

    pub fn family(&self) -> PatternFamily {
        match self {
            KernelVariant::DenseBlocked | KernelVariant::DenseParallel => PatternFamily::Dense,
            KernelVariant::TwFused | KernelVariant::TwParallel => PatternFamily::Tw,
            KernelVariant::TvwFused | KernelVariant::TvwParallel => PatternFamily::Tvw,
            KernelVariant::Vw24 | KernelVariant::Vw24Parallel => PatternFamily::Vw24,
        }
    }
}

/// One point in the search space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Candidate {
    pub variant: KernelVariant,
    pub tile: TileConfig,
    /// TW tile granularity G (plan-encode axis; ignored by dense / VW-4).
    pub g: usize,
    /// Worker threads (1 for serial variants).
    pub threads: usize,
    /// Numeric precision of the kernel (quantize-at-pack axis).
    pub precision: Precision,
}

impl Candidate {
    pub fn label(&self) -> String {
        format!(
            "{}[bm{},bk{},g{},t{},{},{}]",
            self.variant.label(),
            self.tile.bm,
            self.tile.bk,
            self.g,
            self.threads,
            self.tile.micro.label(),
            self.precision.label()
        )
    }

    /// The repo's historical hard-coded configuration for a family —
    /// what every call site used before the autotuner existed.
    pub fn default_for(family: PatternFamily) -> Candidate {
        match family {
            PatternFamily::Dense => Candidate {
                variant: KernelVariant::DenseBlocked,
                tile: TileConfig::dense_default(),
                g: 0,
                threads: 1,
                precision: Precision::Fp32,
            },
            PatternFamily::Tw => Candidate {
                variant: KernelVariant::TwFused,
                tile: TileConfig::tw_default(),
                g: 64,
                threads: 1,
                precision: Precision::Fp32,
            },
            PatternFamily::Tvw => Candidate {
                variant: KernelVariant::TvwFused,
                tile: TileConfig::tvw_default(),
                g: 64,
                threads: 1,
                precision: Precision::Fp32,
            },
            PatternFamily::Vw24 => Candidate {
                variant: KernelVariant::Vw24,
                tile: TileConfig::vw_default(),
                g: 0,
                threads: 1,
                precision: Precision::Fp32,
            },
        }
    }
}

/// Enumeration bounds for the candidate axes.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// Row-block extents.
    pub bms: Vec<usize>,
    /// Reduction-block extents (dense kernel only).
    pub bks: Vec<usize>,
    /// TW tile granularities.
    pub gs: Vec<usize>,
    /// Thread counts (always includes 1).
    pub threads: Vec<usize>,
    /// Microkernel requests crossed with every blocking (the inner-loop
    /// axis: scalar loops vs the detected ISA's register blocks).
    pub micros: Vec<MicroCfg>,
    /// Numeric precisions crossed with every candidate (the
    /// quantize-at-pack axis).  `Auto` is a pack-time *resolution* mode,
    /// never a measured point — only concrete precisions belong here.
    pub precisions: Vec<Precision>,
}

impl Default for SearchSpace {
    fn default() -> Self {
        SearchSpace {
            bms: vec![16, 32, 64, 128],
            bks: vec![32, 64, 128],
            gs: vec![16, 32, 64, 128],
            threads: vec![1],
            micros: micro::search_axis(),
            precisions: vec![Precision::Fp32, Precision::Int8],
        }
    }
}

impl SearchSpace {
    /// Extend the thread axis up to `max_threads` (1 stays in the set so
    /// serial execution is always a candidate).
    pub fn with_threads(mut self, max_threads: usize) -> SearchSpace {
        let mut ts = vec![1];
        if max_threads >= 2 {
            ts.push(2);
        }
        if max_threads > 2 {
            ts.push(max_threads);
        }
        ts.dedup();
        self.threads = ts;
        self
    }

    /// All candidates for executing `shape` under `family`, clipped to the
    /// problem (row blocks beyond M and granularities beyond N are
    /// redundant).  Never empty: the family default is always included.
    pub fn candidates(&self, shape: GemmShape, family: PatternFamily) -> Vec<Candidate> {
        let mut out = Vec::new();
        let bms: Vec<usize> =
            dedup_clipped(&self.bms, shape.m.max(1)).into_iter().collect();
        let gs: Vec<usize> = dedup_clipped(&self.gs, shape.n.max(1)).into_iter().collect();
        match family {
            PatternFamily::Dense => {
                for &bm in &bms {
                    for &bk in &dedup_clipped(&self.bks, shape.k.max(1)) {
                        out.push(Candidate {
                            variant: KernelVariant::DenseBlocked,
                            tile: TileConfig::new(bm, bk),
                            g: 0,
                            threads: 1,
                            precision: Precision::Fp32,
                        });
                    }
                }
                for &t in &self.threads {
                    if t > 1 {
                        out.push(Candidate {
                            variant: KernelVariant::DenseParallel,
                            tile: TileConfig::dense_default(),
                            g: 0,
                            threads: t,
                            precision: Precision::Fp32,
                        });
                    }
                }
            }
            PatternFamily::Tw => {
                for &g in &gs {
                    for &bm in &bms {
                        out.push(Candidate {
                            variant: KernelVariant::TwFused,
                            tile: TileConfig::new(bm, 64),
                            g,
                            threads: 1,
                            precision: Precision::Fp32,
                        });
                    }
                    for &t in &self.threads {
                        if t > 1 {
                            out.push(Candidate {
                                variant: KernelVariant::TwParallel,
                                tile: TileConfig::tw_default(),
                                g,
                                threads: t,
                                precision: Precision::Fp32,
                            });
                        }
                    }
                }
            }
            PatternFamily::Tvw => {
                for &g in &gs {
                    for &bm in &bms {
                        out.push(Candidate {
                            variant: KernelVariant::TvwFused,
                            tile: TileConfig::new(bm, 64),
                            g,
                            threads: 1,
                            precision: Precision::Fp32,
                        });
                    }
                    for &t in &self.threads {
                        if t > 1 {
                            out.push(Candidate {
                                variant: KernelVariant::TvwParallel,
                                tile: TileConfig::tvw_default(),
                                g,
                                threads: t,
                                precision: Precision::Fp32,
                            });
                        }
                    }
                }
            }
            PatternFamily::Vw24 => {
                for &bm in &bms {
                    out.push(Candidate {
                        variant: KernelVariant::Vw24,
                        tile: TileConfig::new(bm, 64),
                        g: 0,
                        threads: 1,
                        precision: Precision::Fp32,
                    });
                }
                for &t in &self.threads {
                    if t > 1 {
                        out.push(Candidate {
                            variant: KernelVariant::Vw24Parallel,
                            tile: TileConfig::vw_default(),
                            g: 0,
                            threads: t,
                            precision: Precision::Fp32,
                        });
                    }
                }
            }
        }
        // microkernel axis: cross every blocking with each requested
        // inner-loop strategy.  The family default keeps `Auto` (resolved
        // at run time), so the historical behaviour stays a measured point.
        let micros: &[MicroCfg] =
            if self.micros.is_empty() { &[MicroCfg::Auto] } else { &self.micros };
        // precision axis: crossed into every candidate, except that the
        // condensed int8 kernels have no pool-parallel entry points — only
        // dense gets int8 x parallel candidates.
        let precisions: &[Precision] =
            if self.precisions.is_empty() { &[Precision::Fp32] } else { &self.precisions };
        let mut crossed: Vec<Candidate> =
            Vec::with_capacity(out.len() * micros.len() * precisions.len());
        for c in &out {
            for &mc in micros {
                for &p in precisions {
                    if p == Precision::Auto {
                        continue;
                    }
                    if p == Precision::Int8
                        && c.variant.is_parallel()
                        && family != PatternFamily::Dense
                    {
                        continue;
                    }
                    let cc = Candidate { tile: c.tile.with_micro(mc), precision: p, ..*c };
                    if !crossed.contains(&cc) {
                        crossed.push(cc);
                    }
                }
            }
        }
        let default = Candidate::default_for(family);
        if !crossed.contains(&default) {
            crossed.push(default);
        }
        crossed
    }
}

/// Clip values to `max`, keep them sorted and unique.
fn dedup_clipped(vals: &[usize], max: usize) -> Vec<usize> {
    let mut v: Vec<usize> = vals.iter().map(|&x| x.max(1).min(max)).collect();
    v.sort_unstable();
    v.dedup();
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_roundtrip() {
        for v in [
            KernelVariant::DenseBlocked,
            KernelVariant::DenseParallel,
            KernelVariant::TwFused,
            KernelVariant::TwParallel,
            KernelVariant::TvwFused,
            KernelVariant::TvwParallel,
            KernelVariant::Vw24,
            KernelVariant::Vw24Parallel,
        ] {
            assert_eq!(KernelVariant::from_label(v.label()), Some(v));
        }
        for f in
            [PatternFamily::Dense, PatternFamily::Tw, PatternFamily::Tvw, PatternFamily::Vw24]
        {
            assert_eq!(PatternFamily::from_label(f.label()), Some(f));
        }
    }

    #[test]
    fn enumeration_contains_default_and_clips() {
        let shape = GemmShape::new(8, 512, 24);
        for family in
            [PatternFamily::Dense, PatternFamily::Tw, PatternFamily::Tvw, PatternFamily::Vw24]
        {
            let cands = SearchSpace::default().candidates(shape, family);
            assert!(!cands.is_empty(), "{family:?}");
            assert!(cands.contains(&Candidate::default_for(family)), "{family:?}");
            for c in &cands {
                assert_eq!(c.variant.family(), family);
            }
        }
        // clipped: no TW granularity beyond N for enumerated candidates
        let tw = SearchSpace::default().candidates(shape, PatternFamily::Tw);
        assert!(tw
            .iter()
            .filter(|c| **c != Candidate::default_for(PatternFamily::Tw))
            .all(|c| c.g <= 24));
    }

    #[test]
    fn micro_axis_crosses_candidates() {
        let shape = GemmShape::new(64, 256, 256);
        let mut space = SearchSpace::default();
        space.micros = vec![MicroCfg::Scalar, MicroCfg::Simd { mr: 4, nr: 16 }];
        let simd = MicroCfg::Simd { mr: 4, nr: 16 };
        for family in
            [PatternFamily::Dense, PatternFamily::Tw, PatternFamily::Tvw, PatternFamily::Vw24]
        {
            let cands = space.candidates(shape, family);
            assert!(cands.iter().any(|c| c.tile.micro == MicroCfg::Scalar), "{family:?}");
            assert!(cands.iter().any(|c| c.tile.micro == simd), "{family:?}");
            // the historical default (micro = Auto) stays a measured point
            assert!(cands.contains(&Candidate::default_for(family)), "{family:?}");
        }
    }

    #[test]
    fn precision_axis_crosses_candidates() {
        let shape = GemmShape::new(64, 256, 256);
        let space = SearchSpace::default().with_threads(4);
        for family in
            [PatternFamily::Dense, PatternFamily::Tw, PatternFamily::Tvw, PatternFamily::Vw24]
        {
            let cands = space.candidates(shape, family);
            assert!(cands.iter().any(|c| c.precision == Precision::Fp32), "{family:?}");
            assert!(cands.iter().any(|c| c.precision == Precision::Int8), "{family:?}");
            // only dense has pool-parallel int8 entry points
            if family != PatternFamily::Dense {
                assert!(
                    cands
                        .iter()
                        .all(|c| !(c.precision == Precision::Int8 && c.variant.is_parallel())),
                    "{family:?}: condensed int8 kernels run serial"
                );
            }
        }
        let dense = space.candidates(shape, PatternFamily::Dense);
        assert!(dense
            .iter()
            .any(|c| c.precision == Precision::Int8 && c.variant.is_parallel()));
        // the label distinguishes the precision axis
        let c = Candidate { precision: Precision::Int8, ..Candidate::default_for(PatternFamily::Tw) };
        assert!(c.label().ends_with(",int8]"), "{}", c.label());
    }

    #[test]
    fn thread_axis_spawns_parallel_variants() {
        let shape = GemmShape::new(256, 256, 256);
        let space = SearchSpace::default().with_threads(8);
        let tw = space.candidates(shape, PatternFamily::Tw);
        assert!(tw.iter().any(|c| c.variant == KernelVariant::TwParallel && c.threads == 8));
        assert!(tw.iter().any(|c| c.threads == 1));
        let dense = space.candidates(shape, PatternFamily::Dense);
        assert!(dense.iter().any(|c| c.variant == KernelVariant::DenseParallel));
        // the paper's headline patterns get parallel candidates too
        let tvw = space.candidates(shape, PatternFamily::Tvw);
        assert!(tvw.iter().any(|c| c.variant == KernelVariant::TvwParallel && c.threads == 8));
        let vw = space.candidates(shape, PatternFamily::Vw24);
        assert!(vw.iter().any(|c| c.variant == KernelVariant::Vw24Parallel && c.threads == 8));
    }
}
