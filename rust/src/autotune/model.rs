//! Analytical pre-filter: rank candidates with the `gpusim` cost model
//! before anything is measured.
//!
//! The plan builders in `gpusim::plans` already price every pattern
//! family's execution strategy (tiled kernels, CTO tables, 2:4 metadata
//! traffic, launch/tile overheads), so the tuner reuses them as a cheap
//! oracle: candidates whose modeled latency is far off the modeled best
//! are dropped without spending wall-clock on them.  CPU cache-blocking
//! (`bm`/`bk`) has no gpusim analogue, so candidates differing only in
//! those axes share a score — the filter prunes across (variant × G) and
//! measurement decides the rest.

use super::space::{Candidate, KernelVariant};
use crate::gpusim::{
    dense_plan, tvw_latency, tw_latency, tw_uniform_tiles, vw24_plan, Calibration, GemmShape,
    GpuSpecs, Pipe, TwStrategy,
};

/// Modeled latency (seconds) of one candidate on `specs`.
///
/// The microkernel axis (`cand.tile.micro`) is deliberately invisible to
/// the model: the gpusim cost substrate has no notion of CPU register
/// blocking, so micro-variants of one blocking score identically and the
/// measured phase alone separates them.  The prefilter keeps ties in
/// enumeration order, so scalar/SIMD twins survive or fall together.
pub fn analytical_cost(
    shape: GemmShape,
    sparsity: f64,
    cand: &Candidate,
    specs: &GpuSpecs,
    cal: &Calibration,
) -> f64 {
    match cand.variant {
        KernelVariant::DenseBlocked | KernelVariant::DenseParallel => {
            dense_plan(shape, Pipe::TensorFp16, specs, cal).latency(specs)
        }
        KernelVariant::TwFused | KernelVariant::TwParallel => {
            let g = cand.g.max(1);
            let tiles = tw_uniform_tiles(shape, sparsity, g);
            tw_latency(shape, &tiles, g, Pipe::TensorFp16, TwStrategy::FusedCto, specs, cal)
        }
        KernelVariant::TvwFused | KernelVariant::TvwParallel => {
            let g = cand.g.max(1);
            // iso-sparsity split: TVW reaches `sparsity` as TW x 2:4
            let s_tw = (1.0 - 2.0 * (1.0 - sparsity)).max(0.0);
            let tiles = tw_uniform_tiles(shape, s_tw, g);
            tvw_latency(shape, &tiles, g, specs, cal)
        }
        KernelVariant::Vw24 | KernelVariant::Vw24Parallel => {
            vw24_plan(shape, false, specs, cal).latency(specs)
        }
    }
}

/// Keep the candidates worth measuring: modeled cost within `slack`× of
/// the modeled best, capped at `max_keep` (cheapest first).  Never empty.
pub fn prefilter(
    cands: &[Candidate],
    shape: GemmShape,
    sparsity: f64,
    slack: f64,
    max_keep: usize,
    specs: &GpuSpecs,
    cal: &Calibration,
) -> Vec<(Candidate, f64)> {
    let mut scored: Vec<(Candidate, f64)> = cands
        .iter()
        .map(|c| (*c, analytical_cost(shape, sparsity, c, specs, cal)))
        .collect();
    scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
    if scored.is_empty() {
        return scored;
    }
    let best = scored[0].1;
    let cutoff = best * slack.max(1.0);
    let mut kept: Vec<(Candidate, f64)> =
        scored.into_iter().filter(|(_, cost)| *cost <= cutoff).collect();
    kept.truncate(max_keep.max(1));
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::space::{PatternFamily, SearchSpace};
    use crate::gpusim::a100;

    #[test]
    fn tw_model_prefers_reasonable_granularity() {
        // at 75% sparsity on a large shape the model must rank TW well
        // under dense (the paper's headline), so a mixed candidate list
        // filters dense-ish losers out
        let specs = a100();
        let cal = Calibration::default();
        let shape = GemmShape::new(1024, 3072, 768);
        let tw = Candidate {
            variant: KernelVariant::TwFused,
            tile: crate::gemm::TileConfig::tw_default(),
            g: 64,
            threads: 1,
            precision: crate::quant::Precision::Fp32,
        };
        let dense = Candidate::default_for(PatternFamily::Dense);
        let c_tw = analytical_cost(shape, 0.75, &tw, &specs, &cal);
        let c_dense = analytical_cost(shape, 0.75, &dense, &specs, &cal);
        assert!(c_tw < c_dense, "tw {c_tw} dense {c_dense}");
    }

    #[test]
    fn prefilter_caps_and_orders() {
        let specs = a100();
        let cal = Calibration::default();
        let shape = GemmShape::new(256, 512, 512);
        let cands = SearchSpace::default().candidates(shape, PatternFamily::Tw);
        let kept = prefilter(&cands, shape, 0.75, 4.0, 5, &specs, &cal);
        assert!(!kept.is_empty());
        assert!(kept.len() <= 5);
        for w in kept.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn prefilter_never_empty_even_with_tight_slack() {
        let specs = a100();
        let cal = Calibration::default();
        let shape = GemmShape::new(64, 64, 64);
        let cands = SearchSpace::default().candidates(shape, PatternFamily::Tvw);
        let kept = prefilter(&cands, shape, 0.8, 1.0, 3, &specs, &cal);
        assert!(!kept.is_empty());
    }
}
