"""Tensor-bundle interchange format between the Python compile path and the
Rust runtime.

``bundle.bin`` is a flat little-endian blob; ``bundle.json`` is an index of
named tensors (name, dtype, shape, byte offset, byte length).  The Rust
side (`runtime::bundle`) mmap-reads the blob and materialises PJRT literals
for the executable arguments listed in ``meta.json`` — no Python at
runtime, no pickle, no framework-specific container.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

__all__ = ["BundleWriter", "DTYPES"]

DTYPES = {"float32": "f32", "int32": "i32"}


class BundleWriter:
    """Accumulates named tensors and writes blob + index."""

    def __init__(self) -> None:
        self._entries: list[dict] = []
        self._chunks: list[bytes] = []
        self._offset = 0
        self._names: set[str] = set()

    def add(self, name: str, array: np.ndarray) -> str:
        if name in self._names:
            raise ValueError(f"duplicate tensor name {name!r}")
        arr = np.ascontiguousarray(array)
        if arr.dtype == np.float64:
            arr = arr.astype(np.float32)
        if arr.dtype == np.int64:
            arr = arr.astype(np.int32)
        if str(arr.dtype) not in DTYPES:
            raise TypeError(f"unsupported dtype {arr.dtype} for {name}")
        raw = arr.tobytes()  # C-order little-endian on all supported hosts
        self._entries.append(
            {
                "name": name,
                "dtype": DTYPES[str(arr.dtype)],
                "shape": list(arr.shape),
                "offset": self._offset,
                "nbytes": len(raw),
            }
        )
        self._chunks.append(raw)
        self._offset += len(raw)
        self._names.add(name)
        return name

    def write(self, out_dir: pathlib.Path, stem: str = "bundle") -> None:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{stem}.bin").write_bytes(b"".join(self._chunks))
        index = {"blob": f"{stem}.bin", "tensors": self._entries}
        (out_dir / f"{stem}.json").write_text(json.dumps(index, indent=1))
