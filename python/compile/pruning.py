"""Build-time implementation of the paper's pruning algorithms (Alg. 1-3).

This module is the *compile-path* (Python) twin of the Rust `pruner` +
`sparse::pattern` modules: it shapes weight matrices into the paper's six
sparsity patterns so that `aot.py` can bake condensed weights + CTO tables
into the runtime artifacts.  All functions are pure numpy and deterministic
(rank-based selection rather than float percentiles) so the Rust
implementation can be golden-tested against JSON fixtures produced here.

Patterns (paper Fig. 2):
  EW   element-wise (unstructured)
  VW   vector-wise n:m along the K (reduction) dimension, e.g. 2:4
  BW   block-wise GxG blocks
  TW   tile-wise: global column pruning, re-tile to width-G tiles, then
       per-tile row pruning with a *global* threshold (Alg. 3 ``TW``)
  TEW  TW overlaid with a small element-wise remedy (Alg. 3 ``TEW``)
  TVW  TW fused with fixed 2:4 VW inside each condensed tile (Alg. 3 ``TVW``)

Conventions: the weight matrix ``w`` has shape (K, N) — K is the GEMM
reduction dimension, N the output dimension — matching the paper's
``C[M,N] = A[M,K] @ B[K,N]``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "importance_element",
    "prune_ew",
    "prune_vw",
    "prune_bw",
    "TwStructure",
    "prune_tw",
    "prune_tew",
    "prune_tvw",
    "multi_stage_prune",
]


# ---------------------------------------------------------------------------
# Importance scores (paper §IV "Importance Score")
# ---------------------------------------------------------------------------

def importance_element(w: np.ndarray, grad: np.ndarray | None = None) -> np.ndarray:
    """Per-element importance.

    Magnitude score |w| by default; if a gradient is supplied, use the
    first-order Taylor score |w * grad| (Molchanov et al. [31]), the
    "incurred error by removing a parameter".
    """
    if grad is None:
        return np.abs(w)
    return np.abs(w * grad)


def _keep_topk_mask(scores: np.ndarray, keep: int) -> np.ndarray:
    """Boolean mask keeping the ``keep`` highest-scoring entries of a 1-D
    score vector.  Rank-based (exact count) rather than percentile-based so
    results are deterministic under ties."""
    flat = scores.reshape(-1)
    keep = int(np.clip(keep, 0, flat.size))
    mask = np.zeros(flat.size, dtype=bool)
    if keep > 0:
        # stable ties: argsort is stable on the negated scores
        idx = np.argsort(-flat, kind="stable")[:keep]
        mask[idx] = True
    return mask.reshape(scores.shape)


# ---------------------------------------------------------------------------
# Algorithm 2: EW / VW / BW
# ---------------------------------------------------------------------------

def prune_ew(w: np.ndarray, sparsity: float, grad: np.ndarray | None = None) -> np.ndarray:
    """Element-wise pruning: keep the top (1-s) fraction of elements
    globally.  Returns a boolean keep-mask of ``w``'s shape."""
    scores = importance_element(w, grad)
    keep = round((1.0 - sparsity) * w.size)
    return _keep_topk_mask(scores, keep)


def prune_vw(w: np.ndarray, sparsity: float, g: int = 4) -> np.ndarray:
    """Vector-wise n:m pruning along the K (reduction) dimension.

    Splits each column of ``w`` (K, N) into vectors of ``g`` consecutive
    elements and keeps the top ``round((1-s)*g)`` elements of every vector
    (balanced sparsity; g=4, s=0.5 is the Ampere sparse-tensor-core 2:4).
    K must be divisible by g.
    """
    k, n = w.shape
    if k % g != 0:
        raise ValueError(f"K={k} not divisible by vector size g={g}")
    keep_per_vec = int(round((1.0 - sparsity) * g))
    scores = np.abs(w).reshape(k // g, g, n)
    # rank within each vector
    order = np.argsort(-scores, axis=1, kind="stable")
    ranks = np.empty_like(order)
    np.put_along_axis(ranks, order, np.arange(g)[None, :, None].repeat(k // g, 0).repeat(n, 2), axis=1)
    mask = ranks < keep_per_vec
    return mask.reshape(k, n)


def prune_bw(w: np.ndarray, sparsity: float, g: int = 16) -> np.ndarray:
    """Block-wise pruning with GxG blocks and a global threshold.

    Ragged edge blocks (when K or N is not a multiple of g) are scored by
    the sum of their valid elements.
    """
    k, n = w.shape
    bk, bn = -(-k // g), -(-n // g)
    padded = np.zeros((bk * g, bn * g), dtype=w.dtype)
    padded[:k, :n] = np.abs(w)
    blocks = padded.reshape(bk, g, bn, g).sum(axis=(1, 3))
    # normalise by valid area so ragged edge blocks compete fairly
    ones = np.zeros((bk * g, bn * g), dtype=np.float64)
    ones[:k, :n] = 1.0
    area = ones.reshape(bk, g, bn, g).sum(axis=(1, 3))
    density = blocks / np.maximum(area, 1.0)
    keep = round((1.0 - sparsity) * blocks.size)
    bmask = _keep_topk_mask(density, keep)
    full = np.repeat(np.repeat(bmask, g, axis=0), g, axis=1)
    return full[:k, :n]


# ---------------------------------------------------------------------------
# Algorithm 3: TW / TEW / TVW
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TwStructure:
    """Structural description of a TW-pruned matrix.

    ``kept_cols``  sorted original column indices that survived TW-C.
    ``tile_rows``  for each width-G tile of the *condensed* column space,
                   the sorted original row indices that survived TW-R.
    ``g``          tile granularity.
    ``shape``      original (K, N).
    """

    kept_cols: np.ndarray          # (Nk,) int64
    tile_rows: list[np.ndarray]    # T entries, each (Kt,) int64
    g: int
    shape: tuple[int, int]

    @property
    def num_tiles(self) -> int:
        return len(self.tile_rows)

    def tile_cols(self, t: int) -> np.ndarray:
        """Original column indices covered by condensed tile ``t``."""
        return self.kept_cols[t * self.g : (t + 1) * self.g]

    def mask(self) -> np.ndarray:
        """Expand to a boolean keep-mask in original (K, N) coordinates."""
        k, n = self.shape
        m = np.zeros((k, n), dtype=bool)
        for t in range(self.num_tiles):
            cols = self.tile_cols(t)
            rows = self.tile_rows[t]
            if len(cols) and len(rows):
                m[np.ix_(rows, cols)] = True
        return m

    def sparsity(self) -> float:
        k, n = self.shape
        kept = sum(len(self.tile_rows[t]) * len(self.tile_cols(t)) for t in range(self.num_tiles))
        return 1.0 - kept / (k * n)


def prune_tw(
    w: np.ndarray,
    sparsity: float,
    g: int = 64,
    col_sparsity: float | None = None,
) -> TwStructure:
    """Tile-wise pruning (Alg. 3 ``TW``).

    Stage 1 (TW-C): score whole columns (K,1 vectors), keep the top
    ``1 - s_c`` fraction; condense the survivors.
    Stage 2 (TW-R): split the condensed matrix into width-``g`` column
    tiles; score each per-tile (1,G) row segment; keep the top ``1 - s_r``
    fraction *globally across tiles* (the paper's global weight pruning).

    The per-stage sparsity follows the paper's equal split
    ``s = 1 - sqrt(1 - s_t)`` unless ``col_sparsity`` overrides stage 1.
    """
    k, n = w.shape
    if col_sparsity is None:
        s_stage = 1.0 - float(np.sqrt(max(0.0, 1.0 - sparsity)))
        s_c = s_r = s_stage
    else:
        s_c = col_sparsity
        # choose s_r so the combined sparsity hits the target
        s_r = 1.0 - (1.0 - sparsity) / max(1e-12, (1.0 - s_c))
        s_r = float(np.clip(s_r, 0.0, 1.0))

    # --- TW-C: column pruning with global ranking ---
    col_scores = np.abs(w).sum(axis=0)
    keep_c = max(1, round((1.0 - s_c) * n))
    col_mask = _keep_topk_mask(col_scores, keep_c)
    kept_cols = np.nonzero(col_mask)[0]
    wc = w[:, kept_cols]                      # condensed (K, Nk)
    nk = wc.shape[1]

    # --- TW-R: per-tile row pruning with a global threshold ---
    # Segments are ranked by importance *density* (score / segment width) and
    # kept greedily until the element budget (1 - s_r) * K * Nk is reached.
    # With N a multiple of G this reduces to the paper's plain percentile
    # over segment scores; with a ragged last tile it keeps the element
    # sparsity on target instead of the segment-count sparsity.
    num_tiles = -(-nk // g)
    widths = np.array(
        [min(g, nk - t * g) for t in range(num_tiles)], dtype=np.int64
    )
    seg_scores = []
    for t in range(num_tiles):
        tile = wc[:, t * g : (t + 1) * g]     # (K, <=G)
        seg_scores.append(np.abs(tile).sum(axis=1))
    seg = np.stack(seg_scores, axis=1)        # (K, T)
    density = seg / widths[None, :]
    target_kept = round((1.0 - s_r) * k * nk)
    order = np.argsort(-density.reshape(-1), kind="stable")
    seg_widths = np.broadcast_to(widths[None, :], seg.shape).reshape(-1)
    csum = np.cumsum(seg_widths[order])
    n_keep = int(np.searchsorted(csum, target_kept, side="right"))
    n_keep = max(n_keep, num_tiles)
    seg_mask = np.zeros(seg.size, dtype=bool)
    seg_mask[order[:n_keep]] = True
    seg_mask = seg_mask.reshape(seg.shape)    # (K, T)
    # guarantee every tile keeps at least one row (an all-empty tile would
    # produce a zero-size GEMM; the paper's condense step has the same
    # invariant implicitly)
    for t in range(num_tiles):
        if not seg_mask[:, t].any():
            seg_mask[np.argmax(seg[:, t]), t] = True

    tile_rows = [np.nonzero(seg_mask[:, t])[0] for t in range(num_tiles)]
    return TwStructure(kept_cols=kept_cols, tile_rows=tile_rows, g=g, shape=(k, n))


def prune_tew(
    w: np.ndarray,
    sparsity: float,
    delta: float,
    g: int = 64,
) -> tuple[TwStructure, np.ndarray]:
    """Tile-element-wise pruning (Alg. 3 ``TEW``).

    Prunes TW at ``sparsity + delta``, then remedies the ``delta`` fraction
    of highest-importance elements *among those TW removed*.  Returns the
    TW structure plus the boolean remedy mask (the CSC-stored EW remainder).
    """
    s = min(0.995, sparsity + delta)
    tw = prune_tw(w, s, g)
    tw_mask = tw.mask()
    scores = importance_element(w).copy()
    scores[tw_mask] = 0.0                     # only consider pruned elements
    remedy_count = round(delta * w.size)
    remedy = _keep_topk_mask(scores, remedy_count)
    remedy &= ~tw_mask
    return tw, remedy


def prune_tvw(w: np.ndarray, sparsity: float, g: int = 64, m: int = 4) -> tuple[TwStructure, np.ndarray]:
    """Tile-vector-wise pruning (Alg. 3 ``TVW``).

    TW at ``s = 1 - 2*(1 - s_t)`` followed by fixed 50% (2:4 when m=4)
    vector-wise pruning along K inside each condensed tile.  Returns the TW
    structure and the final keep-mask in original coordinates (TW mask with
    half of each 4-row group of *condensed* rows dropped).

    Requires ``sparsity >= 0.5`` — the sparse tensor core's fixed 2:4 floor
    (paper §VI-C: "the curve of TVW-4 can only start from 50%").
    """
    if sparsity < 0.5 - 1e-9:
        raise ValueError("TVW sparsity must be >= 0.5 (fixed 2:4 floor)")
    s_tw = 1.0 - 2.0 * (1.0 - sparsity)
    tw = prune_tw(w, s_tw, g)
    # VW 50% within each condensed tile, along the condensed K dimension.
    mask = np.zeros(w.shape, dtype=bool)
    half = m // 2
    for t in range(tw.num_tiles):
        rows = tw.tile_rows[t]
        cols = tw.tile_cols(t)
        if len(rows) == 0 or len(cols) == 0:
            continue
        sub = np.abs(w[np.ix_(rows, cols)])   # (Kt, <=G) condensed tile
        kt = sub.shape[0]
        pad = (-kt) % m
        if pad:
            sub = np.vstack([sub, np.zeros((pad, sub.shape[1]), dtype=sub.dtype)])
        groups = sub.reshape(-1, m, sub.shape[1])
        order = np.argsort(-groups, axis=1, kind="stable")
        ranks = np.empty_like(order)
        np.put_along_axis(
            ranks, order,
            np.broadcast_to(np.arange(m)[None, :, None], order.shape).copy(),
            axis=1,
        )
        keep = (ranks < half).reshape(-1, sub.shape[1])[:kt]
        mask[np.ix_(rows, cols)] = keep
    return tw, mask


# ---------------------------------------------------------------------------
# Algorithm 1: multi-stage prune -> fine-tune schedule
# ---------------------------------------------------------------------------

def multi_stage_prune(
    w: np.ndarray,
    target_sparsity: float,
    step: float,
    prune_fn,
    fine_tune_fn=None,
):
    """Multi-stage pruning (Alg. 1): repeatedly raise the sparsity target by
    ``step``, prune with ``prune_fn(w, s_t)``, and let ``fine_tune_fn``
    adjust the surviving weights.  Returns ``(w, last_prune_result)``.

    ``prune_fn`` must return either a keep-mask or a ``TwStructure``; the
    weight matrix is re-masked after every stage, mirroring the paper's
    prune→fine-tune loop.
    """
    w = w.copy()
    s_t, result = 0.0, None
    while s_t < target_sparsity - 1e-9:
        s_t = min(target_sparsity, s_t + step)
        result = prune_fn(w, s_t)
        if isinstance(result, TwStructure):
            mask = result.mask()
        elif isinstance(result, tuple):  # (TwStructure, extra mask)
            tw, extra = result
            mask = tw.mask() | extra
        else:
            mask = result
        w = np.where(mask, w, 0.0)
        if fine_tune_fn is not None:
            w = fine_tune_fn(w, mask)
    return w, result
