"""Compressed-Tile-Offset (CTO) execution plans for TW / TVW GEMM.

The paper's §V executes a TW-pruned GEMM by condensing each weight tile
offline (removing pruned rows/columns), then running one fused kernel that
*gathers* the needed rows of A via a per-tile row-index table (``CTO_k``)
and *scatters* the output columns via a column-index table (``CTO_n``).

This module turns a :class:`pruning.TwStructure` into the fixed-shape,
padded arrays the Pallas kernels (and the Rust runtime) consume:

``TwPlan``
    b_cond   (T, Kmax, G) f32 — condensed tile values, zero padded
    row_idx  (T, Kmax)    i32 — original row index per condensed row
                                 (padding rows point at 0; their b_cond row
                                 is zero so the gathered A values are
                                 multiplied by 0)
    row_len  (T,)         i32 — valid rows per tile
    col_idx  (T, G)       i32 — original column index per condensed column
                                 (padding columns use the sentinel N, which
                                 the scatter drops as out-of-bounds)

``TvwPlan`` additionally compresses ``b_cond`` 2:4 along the condensed K
dimension into ``b_vals (T, Kmax/2, G)`` + ``b_sel (T, Kmax/2, G)`` where
``b_sel`` holds the in-group position (0..3) of each kept value, i.e. the
sparse-tensor-core metadata word.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .pruning import TwStructure

__all__ = ["TwPlan", "TvwPlan", "Vw24Plan", "encode_tw", "encode_tvw", "encode_vw24"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass
class TwPlan:
    """Padded CTO arrays for one TW-pruned weight matrix (see module doc)."""

    b_cond: np.ndarray   # (T, Kmax, G) f32
    row_idx: np.ndarray  # (T, Kmax) i32
    row_len: np.ndarray  # (T,) i32
    col_idx: np.ndarray  # (T, G) i32, sentinel = N for padding
    n: int               # original N (output width)
    k: int               # original K (reduction size)

    @property
    def num_tiles(self) -> int:
        return self.b_cond.shape[0]

    @property
    def kmax(self) -> int:
        return self.b_cond.shape[1]

    @property
    def g(self) -> int:
        return self.b_cond.shape[2]

    def flops(self, m_rows: int) -> int:
        """MACs*2 actually executed by the condensed GEMM for M=m_rows."""
        return int(2 * m_rows * self.g * int(self.row_len.sum()))

    def dense_flops(self, m_rows: int) -> int:
        return 2 * m_rows * self.k * self.n


def encode_tw(w: np.ndarray, tw: TwStructure, kmax_multiple: int = 8) -> TwPlan:
    """Encode a TW structure over weight matrix ``w`` into padded CTO arrays."""
    k, n = tw.shape
    g = tw.g
    t_count = tw.num_tiles
    kmax = _round_up(max((len(r) for r in tw.tile_rows), default=1), kmax_multiple)
    kmax = max(kmax, kmax_multiple)

    b_cond = np.zeros((t_count, kmax, g), dtype=np.float32)
    row_idx = np.zeros((t_count, kmax), dtype=np.int32)
    row_len = np.zeros((t_count,), dtype=np.int32)
    col_idx = np.full((t_count, g), n, dtype=np.int32)  # sentinel N

    for t in range(t_count):
        rows = tw.tile_rows[t]
        cols = tw.tile_cols(t)
        row_len[t] = len(rows)
        row_idx[t, : len(rows)] = rows
        col_idx[t, : len(cols)] = cols
        if len(rows) and len(cols):
            b_cond[t, : len(rows), : len(cols)] = w[np.ix_(rows, cols)]
    return TwPlan(b_cond=b_cond, row_idx=row_idx, row_len=row_len, col_idx=col_idx, n=n, k=k)


@dataclasses.dataclass
class TvwPlan:
    """TW plan whose condensed tiles are further 2:4-compressed along K."""

    b_vals: np.ndarray   # (T, Kmax//2, G) f32 — kept values
    b_sel: np.ndarray    # (T, Kmax//2, G) i32 — in-group position 0..3
    row_idx: np.ndarray  # (T, Kmax) i32
    row_len: np.ndarray  # (T,) i32
    col_idx: np.ndarray  # (T, G) i32
    n: int
    k: int

    @property
    def num_tiles(self) -> int:
        return self.b_vals.shape[0]

    @property
    def kmax(self) -> int:
        return self.row_idx.shape[1]

    @property
    def g(self) -> int:
        return self.b_vals.shape[2]

    def flops(self, m_rows: int) -> int:
        # the sparse tensor core executes only the kept half of each vector
        return int(2 * m_rows * self.g * int(self.row_len.sum())) // 2


def encode_tvw(w: np.ndarray, tw: TwStructure, tvw_mask: np.ndarray) -> TvwPlan:
    """Encode a TVW pruning result (TW structure + final keep mask) into a
    2:4-compressed CTO plan.  ``tvw_mask`` must keep exactly 2 elements per
    4-row group of condensed rows (zero-padded groups keep the 2 largest,
    which are zeros — still a valid 2:4 encoding)."""
    base = encode_tw(np.where(tvw_mask, w, 0.0).astype(np.float32), tw, kmax_multiple=8)
    t_count, kmax, g = base.b_cond.shape
    assert kmax % 4 == 0
    groups = base.b_cond.reshape(t_count, kmax // 4, 4, g)
    mag = np.abs(groups)
    # positions of the two largest magnitudes per group, sorted ascending
    order = np.argsort(-mag, axis=2, kind="stable")[:, :, :2, :]
    sel = np.sort(order, axis=2).astype(np.int32)          # (T, Kmax/4, 2, G)
    vals = np.take_along_axis(groups, sel, axis=2).astype(np.float32)
    b_sel = sel.reshape(t_count, kmax // 2, g)
    b_vals = vals.reshape(t_count, kmax // 2, g)
    return TvwPlan(
        b_vals=b_vals, b_sel=b_sel,
        row_idx=base.row_idx, row_len=base.row_len, col_idx=base.col_idx,
        n=base.n, k=base.k,
    )


@dataclasses.dataclass
class Vw24Plan:
    """Plain 2:4 compression of a full (K, N) matrix along K (the Ampere
    sparse-tensor-core storage format: values + 2-bit metadata)."""

    b_vals: np.ndarray  # (K//2, N) f32
    b_sel: np.ndarray   # (K//2, N) i32 in [0,4)
    k: int
    n: int


def encode_vw24(w: np.ndarray, mask: np.ndarray) -> Vw24Plan:
    """Compress a 2:4-masked matrix.  ``mask`` must keep exactly 2 of every
    4 consecutive elements along K."""
    k, n = w.shape
    assert k % 4 == 0, "K must be a multiple of 4 for 2:4 compression"
    wm = np.where(mask, w, 0.0).astype(np.float32)
    groups = wm.reshape(k // 4, 4, n)
    gmask = mask.reshape(k // 4, 4, n)
    counts = gmask.sum(axis=1)
    if not np.all(counts == 2):
        raise ValueError("mask is not exactly 2:4 along K")
    # indices of the two kept positions, ascending
    sel = np.argsort(~gmask, axis=1, kind="stable")[:, :2, :]
    sel = np.sort(sel, axis=1).astype(np.int32)
    vals = np.take_along_axis(groups, sel, axis=1).astype(np.float32)
    return Vw24Plan(
        b_vals=vals.reshape(k // 2, n),
        b_sel=sel.reshape(k // 2, n),
        k=k, n=n,
    )


# ---------------------------------------------------------------------------
# Decoders (test/debug): expand plans back to dense masked matrices.
# ---------------------------------------------------------------------------

def decode_tw(plan: TwPlan) -> np.ndarray:
    """Expand a TwPlan back to the dense (K, N) masked weight matrix."""
    w = np.zeros((plan.k, plan.n), dtype=np.float32)
    t_count, kmax, g = plan.b_cond.shape
    for t in range(t_count):
        kt = int(plan.row_len[t])
        rows = plan.row_idx[t, :kt]
        cols = plan.col_idx[t]
        valid = cols < plan.n
        w[np.ix_(rows, cols[valid])] = plan.b_cond[t][:kt][:, valid]
    return w


def decode_tvw(plan: TvwPlan) -> np.ndarray:
    """Expand a TvwPlan back to the dense (K, N) masked weight matrix."""
    t_count, khalf, g = plan.b_vals.shape
    kmax = khalf * 2
    b_cond = np.zeros((t_count, kmax, g), dtype=np.float32)
    grp = (np.arange(khalf) // 2) * 4
    for t in range(t_count):
        rows = grp[:, None] + plan.b_sel[t]
        cols = np.broadcast_to(np.arange(g)[None, :], (khalf, g))
        b_cond[t][rows.reshape(-1), cols.reshape(-1)] = plan.b_vals[t].reshape(-1)
    base = TwPlan(
        b_cond=b_cond, row_idx=plan.row_idx, row_len=plan.row_len,
        col_idx=plan.col_idx, n=plan.n, k=plan.k,
    )
    return decode_tw(base)


def decode_vw24(plan: Vw24Plan) -> np.ndarray:
    """Expand 2:4 storage back to the dense (K, N) masked matrix."""
    khalf, n = plan.b_vals.shape
    w = np.zeros((plan.k, plan.n), dtype=np.float32)
    rows = ((np.arange(khalf) // 2) * 4)[:, None] + plan.b_sel
    cols = np.broadcast_to(np.arange(n)[None, :], (khalf, n))
    w[rows.reshape(-1), cols.reshape(-1)] = plan.b_vals.reshape(-1)
    return w
