"""AOT compile path: lower every model variant + standalone GEMM kernel to
HLO **text** and emit the runtime artifact set.

Interchange is HLO text, NOT ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (``artifacts/``):
  model_{dense,tw,tvw}.hlo.txt   encoder-stack executables
  gemm_{dense,tw,vw24,tvw}.hlo.txt  single-GEMM executables (quickstart +
                                    kernel microbenches)
  bundle.bin / bundle.json       every runtime argument tensor (weights,
                                 condensed tiles, CTO tables, 2:4 payloads)
  meta.json                      executable index: HLO file, activation
                                 spec, argument tensor names (bundle keys),
                                 output shape

Run once via ``make artifacts``; Python never appears on the request path.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import bundle, golden, model, plans, pruning
from .kernels import dense_matmul, tw_matmul, tvw_matmul, vw24_matmul
from .kernels.tew_gemm import encode_remedy_coo, tew_matmul

# Standalone-GEMM artifact configuration (kept small so `make artifacts`
# stays fast; the gpusim benches sweep the paper's 4096^3 shape analytically).
GEMM_M, GEMM_K, GEMM_N = 256, 512, 512
GEMM_G = 64
GEMM_SPARSITY = 0.75


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the crate-safe format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_of(arr: np.ndarray) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(arr.shape, arr.dtype)


def lower_model_variant(
    spec: model.ModelSpec,
    variant: str,
    params: dict[str, np.ndarray],
    batch: int,
    seq: int,
    writer: bundle.BundleWriter,
) -> dict:
    """Prune (if sparse), lower to HLO text, register argument tensors."""
    pruned = model.prune_params(params, spec, variant)
    args = model.flatten_args(params, spec, variant, pruned)
    apply_fn = model.make_apply(spec, variant)
    x_spec = jax.ShapeDtypeStruct((batch, seq, spec.d_model), jnp.float32)
    lowered = jax.jit(apply_fn).lower(x_spec, *[_spec_of(a) for _, a in args])
    text = to_hlo_text(lowered)
    arg_names = []
    for name, arr in args:
        key = f"model_{variant}/{name}"
        writer.add(key, arr)
        arg_names.append(key)
    return {
        "hlo": f"model_{variant}.hlo.txt",
        "kind": "model",
        "activation": {"shape": [batch, seq, spec.d_model], "dtype": "f32"},
        "args": arg_names,
        "output_shape": [batch, spec.n_classes],
        "hlo_text": text,
    }


def lower_gemms(writer: bundle.BundleWriter, seed: int = 7) -> dict[str, dict]:
    """Standalone single-GEMM executables for all four kernels."""
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((GEMM_K, GEMM_N)) / np.sqrt(GEMM_K)).astype(np.float32)
    a_spec = jax.ShapeDtypeStruct((GEMM_M, GEMM_K), jnp.float32)
    out: dict[str, dict] = {}

    def entry(name, fn, arg_arrays, extra_static=()):
        arg_names = []
        for aname, arr in arg_arrays:
            key = f"{name}/{aname}"
            writer.add(key, arr)
            arg_names.append(key)
        lowered = jax.jit(fn).lower(a_spec, *[_spec_of(arr) for _, arr in arg_arrays])
        out[name] = {
            "hlo": f"{name}.hlo.txt",
            "kind": "gemm",
            "activation": {"shape": [GEMM_M, GEMM_K], "dtype": "f32"},
            "args": arg_names,
            "output_shape": [GEMM_M, GEMM_N],
            "hlo_text": to_hlo_text(lowered),
        }

    # dense
    entry("gemm_dense", lambda x, b: dense_matmul(x, b), [("w", w)])

    # TW
    tw = pruning.prune_tw(w, GEMM_SPARSITY, g=GEMM_G)
    p = plans.encode_tw(w, tw)
    entry(
        "gemm_tw",
        lambda x, bc, ri, ci: tw_matmul(x, bc, ri, ci, n=GEMM_N),
        [("b_cond", p.b_cond), ("row_idx", p.row_idx), ("col_idx", p.col_idx)],
    )

    # VW 2:4
    mask24 = pruning.prune_vw(w, 0.5, 4)
    vp = plans.encode_vw24(w, mask24)
    entry(
        "gemm_vw24",
        lambda x, bv, bs: vw24_matmul(x, bv, bs),
        [("b_vals", vp.b_vals), ("b_sel", vp.b_sel)],
    )

    # TEW: TW at s+delta plus the padded COO remainder
    delta = 0.02
    tws, remedy = pruning.prune_tew(w, GEMM_SPARSITY, delta, g=GEMM_G)
    pt = plans.encode_tw(w, tws)
    nnz_pad = int(np.ceil(remedy.sum() / 256) * 256)
    r_vals, r_rows, r_cols = encode_remedy_coo(w, remedy, nnz_pad)
    entry(
        "gemm_tew",
        lambda x, bc, ri, ci, rv, rr, rc: tew_matmul(x, bc, ri, ci, rv, rr, rc, n=GEMM_N),
        [
            ("b_cond", pt.b_cond), ("row_idx", pt.row_idx), ("col_idx", pt.col_idx),
            ("r_vals", r_vals), ("r_rows", r_rows), ("r_cols", r_cols),
        ],
    )

    # TVW
    tw2, mask = pruning.prune_tvw(w, GEMM_SPARSITY, g=GEMM_G)
    q = plans.encode_tvw(w, tw2, mask)
    entry(
        "gemm_tvw",
        lambda x, bv, bs, ri, ci: tvw_matmul(x, bv, bs, ri, ci, n=GEMM_N),
        [
            ("b_vals", q.b_vals),
            ("b_sel", q.b_sel),
            ("row_idx", q.row_idx),
            ("col_idx", q.col_idx),
        ],
    )
    return out


def lower_train(
    spec: model.ModelSpec,
    params: dict,
    batch: int,
    seq: int,
    writer: bundle.BundleWriter,
    lr: float = 0.05,
) -> dict:
    """Lower one SGD train step to HLO text; initial parameters go into the
    bundle so the Rust fine-tuning driver can seed its state."""
    args = model.flatten_args(params, spec, "dense", {})
    step = model.make_train_step(spec, lr=lr)
    x_spec = jax.ShapeDtypeStruct((batch, seq, spec.d_model), jnp.float32)
    y_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    lowered = jax.jit(step).lower(x_spec, y_spec, *[_spec_of(a) for _, a in args])
    arg_names = []
    for name, arr in args:
        key = f"train_dense/{name}"
        writer.add(key, arr)
        arg_names.append(key)
    return {
        "hlo": "train_dense.hlo.txt",
        "kind": "train",
        "inputs": [
            {"shape": [batch, seq, spec.d_model], "dtype": "f32"},
            {"shape": [batch], "dtype": "i32"},
        ],
        "activation": {"shape": [batch, seq, spec.d_model], "dtype": "f32"},
        "args": arg_names,
        "output_shape": [],
        "output_shapes": [[]] + [list(arr.shape) for _, arr in args],
        "hlo_text": to_hlo_text(lowered),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=1024)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--sparsity", type=float, default=0.75)
    ap.add_argument("--granularity", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    spec = model.ModelSpec(
        d_model=args.d_model,
        n_heads=args.n_heads,
        d_ff=args.d_ff,
        n_layers=args.n_layers,
        sparsity=args.sparsity,
        granularity=args.granularity,
    )
    params = model.init_params(args.seed, spec)

    writer = bundle.BundleWriter()
    executables: dict[str, dict] = {}
    for variant in ("dense", "tw", "tvw"):
        print(f"[aot] lowering model_{variant} ...")
        executables[f"model_{variant}"] = lower_model_variant(
            spec, variant, params, args.batch, args.seq, writer
        )
    print("[aot] lowering train step ...")
    executables["train_dense"] = lower_train(spec, params, args.batch, args.seq, writer)
    print("[aot] lowering standalone GEMMs ...")
    executables.update(lower_gemms(writer))

    for name, entry in executables.items():
        text = entry.pop("hlo_text")
        (out_dir / entry["hlo"]).write_text(text)
        print(f"[aot]   {entry['hlo']}: {len(text)} chars")

    writer.write(out_dir)
    golden.write(out_dir)
    print("[aot] wrote golden.json cross-language fixture")
    meta = {
        "spec": dataclasses.asdict(spec),
        "batch": args.batch,
        "seq": args.seq,
        "executables": executables,
    }
    (out_dir / "meta.json").write_text(json.dumps(meta, indent=1))
    print(f"[aot] wrote {out_dir}/meta.json ({len(executables)} executables)")


if __name__ == "__main__":
    main()
