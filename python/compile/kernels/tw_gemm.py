"""Layer-1 Pallas kernel: fused tile-wise (TW) GEMM with CTO (paper §V).

One kernel covers *all* condensed tiles — the paper's "Tile Fusion and
Compressed Tile Offset" optimization (Fig. 4 step 5/6, Listing 1) — instead
of one kernel launch per tile:

  grid = (T, M/Tm): program (t, i) computes the (Tm x G) output block of
  condensed tile t for row block i.
    1. load the A row-block (Tm x K) staged by BlockSpec,
    2. gather the Kmax needed columns with the CTO row table (``CTO_k`` in
       Listing 1) — padding entries index column 0 but multiply a zeroed
       row of the condensed tile, so they contribute nothing,
    3. MXU matmul against the condensed tile (Kmax x G),
  and the surrounding jnp scatter places each tile's G columns at their
  original positions via the CTO column table (``CTO_n``), dropping the
  sentinel (==N) padding columns.  The gather/compute and the scatter lower
  into one fused XLA executable — the single-kernel execution of §V.

The uncoalesced-access analysis of Fig. 4 applies to the *GPU* data path;
here the layout cost shows up in `gpusim` (Rust), while this kernel gets
the numerics bit-exact against ``ref.ref_tw_condensed`` / ``ref_masked``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import scatter_tiles

__all__ = ["tw_matmul", "tw_matmul_tiles"]


def _tw_kernel(a_ref, idx_ref, b_ref, o_ref):
    """One (Tm, G) output block of one condensed tile.

    a_ref   (Tm, K)    A row block (full reduction width)
    idx_ref (1, Kmax)  CTO row offsets for this tile
    b_ref   (1, Kmax, G) condensed tile values
    o_ref   (1, Tm, G)
    """
    a = a_ref[...]
    idx = idx_ref[0, :]
    b = b_ref[0]
    a_g = jnp.take(a, idx, axis=1)        # (Tm, Kmax) CTO gather
    o_ref[0] = jnp.dot(a_g, b, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m",))
def tw_matmul_tiles(a, b_cond, row_idx, *, block_m: int = 128):
    """Run the fused TW kernel and return per-tile outputs ``(T, M, G)``.

    ``a`` (M, K); ``b_cond`` (T, Kmax, G); ``row_idx`` (T, Kmax) int32.
    M is zero-padded to a multiple of ``block_m``.
    """
    m, k = a.shape
    t, kmax, g = b_cond.shape
    bm = min(block_m, m)
    pad_m = (-m) % bm
    ap = jnp.pad(a, ((0, pad_m), (0, 0))) if pad_m else a
    mp = ap.shape[0]
    grid = (t, mp // bm)
    cc = pl.pallas_call(
        _tw_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda tt, i: (i, 0)),
            pl.BlockSpec((1, kmax), lambda tt, i: (tt, 0)),
            pl.BlockSpec((1, kmax, g), lambda tt, i: (tt, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, g), lambda tt, i: (tt, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, mp, g), a.dtype),
        interpret=True,
    )(ap, row_idx, b_cond)
    return cc[:, :m, :]


@functools.partial(jax.jit, static_argnames=("n", "block_m"))
def tw_matmul(a, b_cond, row_idx, col_idx, *, n: int, block_m: int = 128):
    """Full TW GEMM: fused-CTO Pallas kernel + column scatter.

    Returns C (M, N) == A @ B_tw where B_tw is the TW-pruned weight whose
    condensed representation is ``(b_cond, row_idx, col_idx)``.
    """
    cc = tw_matmul_tiles(a, b_cond, row_idx, block_m=block_m)
    return scatter_tiles(cc, col_idx, a.shape[0], n)
