"""Pure-jnp correctness oracles for every Pallas kernel.

Each ``ref_*`` function computes the same mathematical result as its
Pallas twin using only straight-line jnp ops — no tiling, no CTO, no
compression tricks — so the pytest suite can ``assert_allclose`` the two.
The TW/TVW oracles additionally exist in a *mask* form (multiply by the
pruning mask and run a dense matmul), which cross-checks the CTO
encode/condense path itself.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "ref_dense",
    "ref_masked",
    "ref_tw_condensed",
    "ref_vw24",
    "ref_tvw_condensed",
    "ref_tew",
    "decode_vw24",
    "scatter_tiles",
]


def ref_dense(a, b):
    """C = A @ B, the dense baseline."""
    return jnp.matmul(a, b)


def ref_masked(a, b, mask):
    """C = A @ (B * mask): any pattern expressed as an element keep-mask."""
    return jnp.matmul(a, b * mask.astype(b.dtype))


def scatter_tiles(cc, col_idx, m, n):
    """Assemble per-tile outputs ``cc (T, M, G)`` into C (M, N) using the
    CTO column table; sentinel indices (== N) are dropped."""
    t, _, g = cc.shape
    flat_cols = col_idx.reshape(-1)                      # (T*G,)
    cc_flat = jnp.transpose(cc, (1, 0, 2)).reshape(m, t * g)
    c = jnp.zeros((m, n), dtype=cc.dtype)
    return c.at[:, flat_cols].set(cc_flat, mode="drop")


def ref_tw_condensed(a, b_cond, row_idx, col_idx, n):
    """TW GEMM straight from the CTO plan, without Pallas.

    For every tile t: gather A columns by ``row_idx[t]`` (padded rows point
    at column 0 but multiply a zero row of ``b_cond``), matmul against the
    condensed tile, scatter the G outputs to their original columns.
    """
    m = a.shape[0]
    ag = a[:, row_idx]                    # (M, T, Kmax) gather
    cc = jnp.einsum("mtk,tkg->tmg", ag, b_cond)
    return scatter_tiles(cc, col_idx, m, n)


def decode_vw24(b_vals, b_sel, k):
    """Decompress 2:4 storage (K/2, N) values + in-group positions back to
    a dense (K, N) matrix."""
    khalf, n = b_vals.shape
    rows = (jnp.arange(khalf) // 2) * 4
    rows = rows[:, None] + b_sel                          # (K/2, N)
    cols = jnp.broadcast_to(jnp.arange(n)[None, :], (khalf, n))
    dense = jnp.zeros((k, n), dtype=b_vals.dtype)
    return dense.at[rows, cols].set(b_vals, mode="drop")


def ref_vw24(a, b_vals, b_sel):
    """2:4 sparse GEMM via explicit decompression."""
    k = a.shape[1]
    return jnp.matmul(a, decode_vw24(b_vals, b_sel, k))


def ref_tvw_condensed(a, b_vals, b_sel, row_idx, col_idx, n):
    """TVW GEMM from the fused plan: per-tile 2:4 decode + CTO gather/scatter."""
    t, khalf, g = b_vals.shape
    kmax = khalf * 2

    def decode_tile(vals, sel):
        rows = (jnp.arange(khalf) // 2) * 4
        rows = rows[:, None] + sel
        cols = jnp.broadcast_to(jnp.arange(g)[None, :], (khalf, g))
        dense = jnp.zeros((kmax, g), dtype=vals.dtype)
        return dense.at[rows, cols].set(vals, mode="drop")

    b_cond = jax.vmap(decode_tile)(b_vals, b_sel)         # (T, Kmax, G)
    return ref_tw_condensed(a, b_cond, row_idx, col_idx, n)


def ref_tew(a, b_cond, row_idx, col_idx, n, remedy_vals, remedy_rows, remedy_cols):
    """TEW = TW condensed GEMM + sparse (COO) remainder of remedied elements.

    The paper executes the two parts separately (TW on the tensor core, the
    EW remainder as CSC on CUDA cores) and sums — the linearity trick of
    §III-A.  ``remedy_*`` are COO triplets; pad with column index >= N to
    have entries dropped.
    """
    c = ref_tw_condensed(a, b_cond, row_idx, col_idx, n)
    # C += outer-product accumulation: A[:, r] * v into column c per nnz
    contrib = a[:, remedy_rows] * remedy_vals[None, :]    # (M, nnz)
    return c.at[:, remedy_cols].add(contrib, mode="drop")
