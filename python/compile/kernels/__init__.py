# L1: Pallas kernels for the paper's compute hot-spots.
from .dense_gemm import dense_matmul
from .tw_gemm import tw_matmul, tw_matmul_tiles
from .vw_gemm import vw24_matmul
from .tvw_gemm import tvw_matmul, tvw_matmul_tiles

__all__ = [
    "dense_matmul",
    "tw_matmul",
    "tw_matmul_tiles",
    "vw24_matmul",
    "tvw_matmul",
    "tvw_matmul_tiles",
]
