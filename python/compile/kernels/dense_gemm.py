"""Layer-1 Pallas kernel: tiled dense GEMM baseline.

The classic three-level schedule the paper's §V builds on: the grid walks
output tiles (Mtile x Ntile) with an inner reduction walk over Ktile; each
program stages an A block and a B block into VMEM (the TPU analogue of the
threadblock's shared-memory tile), accumulates partial sums in the output
block, and the MXU executes the per-block matmul.

Hardware adaptation (DESIGN.md §1): the paper's CUTLASS threadblock /
warp / fragment hierarchy maps to BlockSpec grid tiles / VMEM blocks /
MXU-internal accumulation.  ``interpret=True`` always — the CPU PJRT
client cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dense_matmul", "DEFAULT_BLOCK"]

# Default (Mtile, Ntile, Ktile).  128x128 output tiles mirror the paper's
# TW-128 configuration; Ktile=128 keeps the VMEM footprint of the two
# staged blocks at 2*128*128*4B = 128 KiB, inside a TPU core's ~16 MiB VMEM
# with ample room for double buffering.
DEFAULT_BLOCK = (128, 128, 128)


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output block; grid axis 2 walks the K reduction."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype)


def _pad_to(x, mult0, mult1):
    m, n = x.shape
    pm, pn = (-m) % mult0, (-n) % mult1
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@functools.partial(jax.jit, static_argnames=("block",))
def dense_matmul(a, b, *, block: tuple[int, int, int] = DEFAULT_BLOCK):
    """C = A @ B with a tiled Pallas kernel.

    Shapes need not be multiples of the block — inputs are zero-padded and
    the result cropped, mirroring CUTLASS's predicated edge tiles.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"reduction mismatch {k} vs {k2}"
    bm, bn, bk = block
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    mp, kp = ap.shape
    _, np_ = bp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]
