"""Layer-1 Pallas kernel: fused tile-vector-wise (TVW) GEMM.

TVW composes the paper's two orthogonal levels in one kernel (§III-A):

  * TW operates at the *global memory* level — condensed tiles, CTO row
    gather of A, CTO column scatter of C (as in ``tw_gemm``);
  * VW (2:4) operates at the *register* level — inside each condensed tile
    B is stored as (Kmax/2, G) values + positions, expanded right before
    the MXU matmul (as in ``vw_gemm``).

grid = (T, M/Tm); program (t, i):
  1. gather A columns via CTO_k,
  2. metadata-expand the tile's 2:4 payload,
  3. MXU matmul → (Tm, G) block,
then the surrounding scatter places columns via CTO_n.  Numerics are
checked against ``ref.ref_tvw_condensed`` and the mask oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import scatter_tiles

__all__ = ["tvw_matmul", "tvw_matmul_tiles"]


def _tvw_kernel(a_ref, idx_ref, v_ref, s_ref, o_ref):
    """a_ref (Tm, K); idx_ref (1, Kmax); v_ref/s_ref (1, Kmax/2, G);
    o_ref (1, Tm, G)."""
    a = a_ref[...]
    idx = idx_ref[0, :]
    vals = v_ref[0]                                     # (Kmax/2, G)
    sel = s_ref[0]
    khalf, g = vals.shape
    kmax = khalf * 2
    # register-level 2:4 expansion of the condensed tile
    rows = (jax.lax.iota(jnp.int32, khalf)[:, None] // 2) * 4 + sel
    cols = jnp.broadcast_to(jax.lax.iota(jnp.int32, g)[None, :], (khalf, g))
    b = jnp.zeros((kmax, g), dtype=vals.dtype).at[rows, cols].set(vals, mode="drop")
    # global-memory-level CTO gather of A
    a_g = jnp.take(a, idx, axis=1)                      # (Tm, Kmax)
    o_ref[0] = jnp.dot(a_g, b, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m",))
def tvw_matmul_tiles(a, b_vals, b_sel, row_idx, *, block_m: int = 128):
    """Fused TVW kernel returning per-tile outputs ``(T, M, G)``."""
    m, k = a.shape
    t, khalf, g = b_vals.shape
    kmax = khalf * 2
    assert row_idx.shape == (t, kmax)
    bm = min(block_m, m)
    pad_m = (-m) % bm
    ap = jnp.pad(a, ((0, pad_m), (0, 0))) if pad_m else a
    mp = ap.shape[0]
    grid = (t, mp // bm)
    cc = pl.pallas_call(
        _tvw_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda tt, i: (i, 0)),
            pl.BlockSpec((1, kmax), lambda tt, i: (tt, 0)),
            pl.BlockSpec((1, khalf, g), lambda tt, i: (tt, 0, 0)),
            pl.BlockSpec((1, khalf, g), lambda tt, i: (tt, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, g), lambda tt, i: (tt, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, mp, g), a.dtype),
        interpret=True,
    )(ap, row_idx, b_vals, b_sel)
    return cc[:, :m, :]


@functools.partial(jax.jit, static_argnames=("n", "block_m"))
def tvw_matmul(a, b_vals, b_sel, row_idx, col_idx, *, n: int, block_m: int = 128):
    """Full TVW GEMM: fused kernel + CTO column scatter → C (M, N)."""
    cc = tvw_matmul_tiles(a, b_vals, b_sel, row_idx, block_m=block_m)
    return scatter_tiles(cc, col_idx, a.shape[0], n)
