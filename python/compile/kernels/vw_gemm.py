"""Layer-1 Pallas kernel: 2:4 vector-wise sparse GEMM (sparse tensor core).

The Ampere sparse tensor core stores B compressed along K — two values out
of every four plus a 2-bit position word — and expands them against the
*selected* A operands inside the MAC array (paper Fig. 1).  On the CPU/TPU
substrate we reproduce the storage format exactly (``b_vals`` (K/2, N) +
``b_sel`` (K/2, N) positions) and perform the metadata-driven expansion in
VMEM before an MXU matmul; the 2x throughput of the real unit is modeled
by `gpusim` (Rust), while this kernel supplies bit-exact numerics against
``ref.ref_vw24``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["vw24_matmul"]


def _vw_kernel(a_ref, v_ref, s_ref, o_ref):
    """One (bm, bn) output block; full-K reduction.

    a_ref (bm, K); v_ref (K/2, bn); s_ref (K/2, bn); o_ref (bm, bn).
    """
    a = a_ref[...]
    vals = v_ref[...]
    sel = s_ref[...]
    khalf, bn = vals.shape
    k = a.shape[1]
    # metadata expansion: value j of compressed row i lives at dense row
    # (i // 2) * 4 + sel[i, j]
    rows = (jax.lax.iota(jnp.int32, khalf)[:, None] // 2) * 4 + sel
    cols = jnp.broadcast_to(jax.lax.iota(jnp.int32, bn)[None, :], (khalf, bn))
    dense = jnp.zeros((k, bn), dtype=vals.dtype).at[rows, cols].set(vals, mode="drop")
    o_ref[...] = jnp.dot(a, dense, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def vw24_matmul(a, b_vals, b_sel, *, block: tuple[int, int] = (128, 128)):
    """C = A @ B where B is 2:4-compressed along K.

    ``a`` (M, K) with K % 4 == 0; ``b_vals``/``b_sel`` (K/2, N).
    """
    m, k = a.shape
    khalf, n = b_vals.shape
    assert khalf * 2 == k, f"compressed K mismatch: {khalf}*2 != {k}"
    bm, bn = min(block[0], m), min(block[1], n)
    pm, pn = (-m) % bm, (-n) % bn
    ap = jnp.pad(a, ((0, pm), (0, 0))) if pm else a
    vp = jnp.pad(b_vals, ((0, 0), (0, pn))) if pn else b_vals
    sp = jnp.pad(b_sel, ((0, 0), (0, pn))) if pn else b_sel
    mp, np_ = ap.shape[0], vp.shape[1]
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        _vw_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((khalf, bn), lambda i, j: (0, j)),
            pl.BlockSpec((khalf, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(ap, vp, sp)
    return out[:m, :n]
