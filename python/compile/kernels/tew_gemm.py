"""Layer-1 Pallas composition: tile-element-wise (TEW) GEMM.

TEW executes as two linear parts (paper §III-A): the TW-condensed GEMM on
the tensor core plus the delta-EW remainder as a sparse (COO) update on
the CUDA cores, summed by linearity.  Here both parts lower into one XLA
executable: the fused-CTO Pallas kernel for the TW part, and a padded COO
scatter-add for the remainder (padding entries carry column index >= N
and are dropped by the scatter).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .tw_gemm import tw_matmul

__all__ = ["tew_matmul", "encode_remedy_coo"]


def encode_remedy_coo(w, remedy_mask, nnz_pad: int):
    """Encode the remedy elements as fixed-size COO arrays.

    Returns (vals, rows, cols) each of length `nnz_pad`; unused slots have
    col == N (the drop sentinel).  Raises if the remedy has more nonzeros
    than `nnz_pad`.
    """
    import numpy as np

    rr, cc = np.nonzero(remedy_mask)
    if len(rr) > nnz_pad:
        raise ValueError(f"remedy nnz {len(rr)} exceeds pad {nnz_pad}")
    n = w.shape[1]
    vals = np.zeros(nnz_pad, dtype=np.float32)
    rows = np.zeros(nnz_pad, dtype=np.int32)
    cols = np.full(nnz_pad, n, dtype=np.int32)
    vals[: len(rr)] = w[rr, cc]
    rows[: len(rr)] = rr
    cols[: len(rr)] = cc
    return vals, rows, cols


@functools.partial(jax.jit, static_argnames=("n", "block_m"))
def tew_matmul(a, b_cond, row_idx, col_idx, r_vals, r_rows, r_cols, *, n: int, block_m: int = 128):
    """C = A @ (B_tw + B_remedy): fused-CTO TW kernel + COO remainder.

    ``r_vals/r_rows/r_cols`` are the padded COO triplets from
    :func:`encode_remedy_coo`.
    """
    c = tw_matmul(a, b_cond, row_idx, col_idx, n=n, block_m=block_m)
    contrib = a[:, r_rows] * r_vals[None, :]      # (M, nnz_pad)
    return c.at[:, r_cols].add(contrib, mode="drop")
