"""Layer-2 JAX model: a BERT-style transformer encoder whose GEMMs route
through the Layer-1 Pallas kernels.

The paper evaluates TW/TVW on BERT by replacing every weight GEMM with the
pattern's sparse GEMM; we do the same on a configurable encoder stack
(MHA + FFN + post-LN, mean-pool + classifier head).  Three weight variants
exist per model:

  dense  — all four per-layer GEMMs through :func:`kernels.dense_matmul`
  tw     — the four weight matrices TW-pruned (Alg. 3) and executed with
           the fused CTO kernel :func:`kernels.tw_matmul`
  tvw    — TVW-pruned and executed with :func:`kernels.tvw_matmul`

All sparse-plan arrays (condensed values, CTO row/col tables, 2:4 payload)
are *runtime arguments*, not baked constants, so the Rust coordinator feeds
them from the artifact bundle and the HLO stays small.  ``aot.py`` lowers
``make_apply(...)`` for each variant to HLO text.

This module is build-time only: it is never imported on the request path.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import plans, pruning
from .kernels import dense_matmul, tw_matmul, tvw_matmul

__all__ = ["ModelSpec", "MATMUL_DEFS", "init_params", "prune_params", "make_apply", "flatten_args"]


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Encoder-stack hyper-parameters (BERT-mini scale by default)."""

    d_model: int = 256
    n_heads: int = 4
    d_ff: int = 1024
    n_layers: int = 2
    n_classes: int = 8
    # pruning hyper-parameters for the sparse variants
    sparsity: float = 0.75
    granularity: int = 64

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def matmul_defs(spec: ModelSpec) -> list[tuple[str, int, int]]:
    """The prunable GEMMs per layer: (name, K, N) with B of shape (K, N)."""
    d, f = spec.d_model, spec.d_ff
    defs = []
    for layer in range(spec.n_layers):
        defs += [
            (f"layer{layer}/wqkv", d, 3 * d),
            (f"layer{layer}/wo", d, d),
            (f"layer{layer}/w1", d, f),
            (f"layer{layer}/w2", f, d),
        ]
    return defs


MATMUL_DEFS = matmul_defs  # legacy alias


def init_params(seed: int, spec: ModelSpec) -> dict[str, np.ndarray]:
    """Xavier-ish initialisation of every parameter tensor (numpy, f32)."""
    rng = np.random.default_rng(seed)
    params: dict[str, np.ndarray] = {}
    for name, k, n in matmul_defs(spec):
        params[name] = (rng.standard_normal((k, n)) / np.sqrt(k)).astype(np.float32)
    for layer in range(spec.n_layers):
        for ln in ("ln1", "ln2"):
            params[f"layer{layer}/{ln}/scale"] = np.ones(spec.d_model, dtype=np.float32)
            params[f"layer{layer}/{ln}/bias"] = np.zeros(spec.d_model, dtype=np.float32)
    params["head"] = (
        rng.standard_normal((spec.d_model, spec.n_classes)) / np.sqrt(spec.d_model)
    ).astype(np.float32)
    return params


def prune_params(
    params: dict[str, np.ndarray], spec: ModelSpec, variant: str
) -> dict[str, object]:
    """Prune every prunable GEMM weight to ``variant`` and encode its plan.

    Returns a dict mapping matmul name -> TwPlan | TvwPlan.  Dense variant
    returns an empty dict.
    """
    out: dict[str, object] = {}
    if variant == "dense":
        return out
    for name, _, _ in matmul_defs(spec):
        w = params[name]
        if variant == "tw":
            tw = pruning.prune_tw(w, spec.sparsity, g=spec.granularity)
            out[name] = plans.encode_tw(w, tw)
        elif variant == "tvw":
            tw, mask = pruning.prune_tvw(w, max(spec.sparsity, 0.5), g=spec.granularity)
            out[name] = plans.encode_tvw(w, tw, mask)
        else:
            raise ValueError(f"unknown variant {variant!r}")
    return out


# ---------------------------------------------------------------------------
# Argument flattening: a deterministic (name, tensor) order shared with the
# Rust side via meta.json.
# ---------------------------------------------------------------------------

def flatten_args(
    params: dict[str, np.ndarray], spec: ModelSpec, variant: str, pruned: dict[str, object]
) -> list[tuple[str, np.ndarray]]:
    """Runtime-argument tensors, in lowering order (activations excluded)."""
    args: list[tuple[str, np.ndarray]] = []
    for name, _, _ in matmul_defs(spec):
        if variant == "dense":
            args.append((name, params[name]))
        elif variant == "tw":
            p: plans.TwPlan = pruned[name]  # type: ignore[assignment]
            args += [
                (f"{name}/b_cond", p.b_cond),
                (f"{name}/row_idx", p.row_idx),
                (f"{name}/col_idx", p.col_idx),
            ]
        else:  # tvw
            q: plans.TvwPlan = pruned[name]  # type: ignore[assignment]
            args += [
                (f"{name}/b_vals", q.b_vals),
                (f"{name}/b_sel", q.b_sel),
                (f"{name}/row_idx", q.row_idx),
                (f"{name}/col_idx", q.col_idx),
            ]
    for layer in range(spec.n_layers):
        for ln in ("ln1", "ln2"):
            args.append((f"layer{layer}/{ln}/scale", params[f"layer{layer}/{ln}/scale"]))
            args.append((f"layer{layer}/{ln}/bias", params[f"layer{layer}/{ln}/bias"]))
    args.append(("head", params["head"]))
    return args


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _layer_norm(x, scale, bias, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def make_apply(spec: ModelSpec, variant: str, block_m: int = 128):
    """Build ``apply(x, *arg_tensors) -> logits`` for one weight variant.

    ``x`` is (B, S, D) activations; ``arg_tensors`` follow the order of
    :func:`flatten_args`.  The function is pure and jittable; ``aot.py``
    lowers it to HLO text.
    """
    n_per_matmul = {"dense": 1, "tw": 3, "tvw": 4}[variant]
    defs = matmul_defs(spec)

    def matmul(x2d, args, mm_index):
        base = mm_index * n_per_matmul
        _, _, n = defs[mm_index]
        if variant == "dense":
            return dense_matmul(x2d, args[base])
        if variant == "tw":
            b_cond, row_idx, col_idx = args[base : base + 3]
            return tw_matmul(x2d, b_cond, row_idx, col_idx, n=n, block_m=block_m)
        b_vals, b_sel, row_idx, col_idx = args[base : base + 4]
        return tvw_matmul(x2d, b_vals, b_sel, row_idx, col_idx, n=n, block_m=block_m)

    def apply(x, *args):
        b, s, d = x.shape
        h, dh = spec.n_heads, spec.d_head
        ln_base = len(defs) * n_per_matmul
        mm = 0
        for layer in range(spec.n_layers):
            x2d = x.reshape(b * s, d)
            # --- multi-head attention ---
            qkv = matmul(x2d, args, mm); mm += 1
            q, k_, v = jnp.split(qkv.reshape(b, s, 3 * d), 3, axis=-1)
            q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
            k_ = k_.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
            v = v.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
            scores = jnp.einsum("bhqd,bhkd->bhqk", q, k_) / np.sqrt(dh)
            attn = jax.nn.softmax(scores, axis=-1)
            ctx = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
            ctx = ctx.transpose(0, 2, 1, 3).reshape(b * s, d)
            proj = matmul(ctx, args, mm); mm += 1
            scale1 = args[ln_base + layer * 4 + 0]
            bias1 = args[ln_base + layer * 4 + 1]
            x = _layer_norm(x + proj.reshape(b, s, d), scale1, bias1)
            # --- feed-forward ---
            x2d = x.reshape(b * s, d)
            hdn = matmul(x2d, args, mm); mm += 1
            hdn = jax.nn.gelu(hdn)
            out = matmul(hdn, args, mm); mm += 1
            scale2 = args[ln_base + layer * 4 + 2]
            bias2 = args[ln_base + layer * 4 + 3]
            x = _layer_norm(x + out.reshape(b, s, d), scale2, bias2)
        pooled = jnp.mean(x, axis=1)                       # (B, D)
        head = args[-1]
        return jnp.matmul(pooled, head)                    # (B, n_classes)

    return apply


# ---------------------------------------------------------------------------
# Training step (build-time lowering; the Rust runtime drives the loop)
# ---------------------------------------------------------------------------

def make_apply_jnp(spec: ModelSpec):
    """Pure-jnp forward (same math as ``make_apply(spec, "dense")`` but
    through native XLA matmuls instead of the Pallas kernels).  Used for
    the training graph: Pallas interpret-mode kernels have no JVP rule,
    and training wants XLA's fused backward anyway — the Pallas kernels
    are the *inference* hot path."""
    defs = matmul_defs(spec)

    def apply(x, *args):
        b, s, d = x.shape
        h, dh = spec.n_heads, spec.d_head
        ln_base = len(defs)
        mm = 0
        for layer in range(spec.n_layers):
            x2d = x.reshape(b * s, d)
            qkv = jnp.matmul(x2d, args[mm]); mm += 1
            q, k_, v = jnp.split(qkv.reshape(b, s, 3 * d), 3, axis=-1)
            q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
            k_ = k_.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
            v = v.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
            scores = jnp.einsum("bhqd,bhkd->bhqk", q, k_) / np.sqrt(dh)
            attn = jax.nn.softmax(scores, axis=-1)
            ctx = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
            ctx = ctx.transpose(0, 2, 1, 3).reshape(b * s, d)
            proj = jnp.matmul(ctx, args[mm]); mm += 1
            scale1 = args[ln_base + layer * 4 + 0]
            bias1 = args[ln_base + layer * 4 + 1]
            x = _layer_norm(x + proj.reshape(b, s, d), scale1, bias1)
            x2d = x.reshape(b * s, d)
            hdn = jax.nn.gelu(jnp.matmul(x2d, args[mm])); mm += 1
            out = jnp.matmul(hdn, args[mm]); mm += 1
            scale2 = args[ln_base + layer * 4 + 2]
            bias2 = args[ln_base + layer * 4 + 3]
            x = _layer_norm(x + out.reshape(b, s, d), scale2, bias2)
        pooled = jnp.mean(x, axis=1)
        return jnp.matmul(pooled, args[-1])

    return apply


def make_train_step(spec: ModelSpec, lr: float = 0.05):
    """Build ``train_step(x, y, *params) -> (loss, *new_params)``.

    Softmax cross-entropy over the classifier head + one SGD step, all
    inside one jitted graph so the Rust fine-tuning driver (the paper's
    Algorithm 1 "FineTune" hook) can run pruning-aware training through
    PJRT with no Python.  Dense math only — pruned variants fine-tune by
    masking the returned weights (the driver re-applies the mask after
    every step, exactly Algorithm 1's prune→fine-tune contract).
    """
    apply_fn = make_apply_jnp(spec)

    def loss_fn(params, x, y):
        logits = apply_fn(x, *params)
        logp = jax.nn.log_softmax(logits)
        picked = jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
        return -jnp.mean(picked)

    def train_step(x, y, *params):
        loss, grads = jax.value_and_grad(loss_fn)(tuple(params), x, y)
        new_params = tuple(p - lr * g for p, g in zip(params, grads))
        return (loss,) + new_params

    return train_step
