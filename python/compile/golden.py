"""Golden cross-language fixtures: prune small matrices with the Python
implementation and dump (weights, masks, plans) as JSON so the Rust twin
(`rust/tests/golden_parity.rs`) can verify bit-identical pattern decisions.

Invoked by aot.py as part of ``make artifacts``.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

from . import plans, pruning


def _mask_to_bits(mask: np.ndarray) -> list[int]:
    return [int(x) for x in mask.reshape(-1)]


def build_fixture(seed: int = 314) -> dict:
    rng = np.random.default_rng(seed)
    k, n, g = 32, 24, 8
    w = rng.normal(size=(k, n)).astype(np.float32)
    fixture: dict = {
        "k": k,
        "n": n,
        "g": g,
        "w": [float(x) for x in w.reshape(-1)],
        "cases": {},
    }

    fixture["cases"]["ew_50"] = _mask_to_bits(pruning.prune_ew(w, 0.5))
    fixture["cases"]["vw4_50"] = _mask_to_bits(pruning.prune_vw(w, 0.5, 4))
    fixture["cases"]["bw8_50"] = _mask_to_bits(pruning.prune_bw(w, 0.5, 8))

    tw = pruning.prune_tw(w, 0.6, g=g)
    fixture["cases"]["tw_60"] = _mask_to_bits(tw.mask())
    plan = plans.encode_tw(w, tw)
    fixture["tw_plan"] = {
        "tiles": plan.num_tiles,
        "kmax": plan.kmax,
        "row_len": [int(x) for x in plan.row_len],
        "col_idx": [int(x) for x in plan.col_idx.reshape(-1)],
        "row_idx": [int(x) for x in plan.row_idx.reshape(-1)],
    }

    tws, remedy = pruning.prune_tew(w, 0.6, 0.05, g=g)
    fixture["cases"]["tew_60_5"] = _mask_to_bits(tws.mask() | remedy)

    twv, tvmask = pruning.prune_tvw(w, 0.75, g=g)
    fixture["cases"]["tvw_75"] = _mask_to_bits(tvmask)
    return fixture


def write(out_dir: pathlib.Path) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / "golden.json").write_text(json.dumps(build_fixture(), indent=1))
