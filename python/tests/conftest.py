"""Shared fixtures for the compile-path test suite."""

import sys
import pathlib

import numpy as np
import pytest

# allow `pytest python/tests` from the repo root as well as `cd python && pytest tests`
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
