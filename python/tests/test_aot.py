"""AOT artifact generation: bundle consistency + HLO loadability."""

import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    """Generate a tiny artifact set once per test module."""
    out = tmp_path_factory.mktemp("artifacts")
    root = pathlib.Path(__file__).resolve().parents[1]
    subprocess.run(
        [
            sys.executable, "-m", "compile.aot",
            "--out", str(out),
            "--batch", "2", "--seq", "8",
            "--d-model", "32", "--n-heads", "2", "--d-ff", "64", "--n-layers", "1",
            "--granularity", "8",
        ],
        cwd=root, check=True, capture_output=True,
    )
    return out


def test_meta_lists_all_executables(artifacts):
    meta = json.loads((artifacts / "meta.json").read_text())
    names = set(meta["executables"])
    assert {"model_dense", "model_tw", "model_tvw",
            "gemm_dense", "gemm_tw", "gemm_vw24", "gemm_tvw"} <= names


def test_hlo_files_exist_and_parse(artifacts):
    meta = json.loads((artifacts / "meta.json").read_text())
    for name, entry in meta["executables"].items():
        text = (artifacts / entry["hlo"]).read_text()
        assert text.startswith("HloModule"), name
        assert "custom-call" not in text, f"{name} contains a custom-call"


def test_bundle_index_consistent(artifacts):
    idx = json.loads((artifacts / "bundle.json").read_text())
    blob = (artifacts / idx["blob"]).read_bytes()
    offset = 0
    for t in idx["tensors"]:
        assert t["offset"] == offset, "tensors must be contiguous"
        elem = 4  # f32 and i32 both 4 bytes
        expect = int(np.prod(t["shape"])) * elem
        assert t["nbytes"] == expect
        offset += t["nbytes"]
    assert offset == len(blob)


def test_meta_args_resolve_in_bundle(artifacts):
    meta = json.loads((artifacts / "meta.json").read_text())
    idx = json.loads((artifacts / "bundle.json").read_text())
    names = {t["name"] for t in idx["tensors"]}
    for entry in meta["executables"].values():
        for arg in entry["args"]:
            assert arg in names, f"missing bundle tensor {arg}"


def test_hlo_text_reparses_as_module(artifacts):
    """The dumped text must round-trip through an HLO text parser — the same
    family of parser the Rust runtime's xla_extension uses.  (Numeric
    execution of the artifacts is covered by the Rust integration tests,
    which exercise the real PJRT load path.)"""
    from jax._src.lib import xla_client as xc

    meta = json.loads((artifacts / "meta.json").read_text())
    for name, entry in meta["executables"].items():
        text = (artifacts / entry["hlo"]).read_text()
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None, name


def test_bundle_dtypes_supported(artifacts):
    idx = json.loads((artifacts / "bundle.json").read_text())
    assert {t["dtype"] for t in idx["tensors"]} <= {"f32", "i32"}


def test_activation_and_output_shapes(artifacts):
    meta = json.loads((artifacts / "meta.json").read_text())
    for name, entry in meta["executables"].items():
        if entry["kind"] == "model":
            b, s, d = entry["activation"]["shape"]
            assert entry["output_shape"][0] == b
        elif entry["kind"] == "train":
            # (x, y) inputs; outputs = (scalar loss, *params)
            assert len(entry["inputs"]) == 2
            assert entry["output_shapes"][0] == []
            assert len(entry["output_shapes"]) == len(entry["args"]) + 1
        else:
            m, k = entry["activation"]["shape"]
            assert entry["output_shape"][0] == m
