"""Layer-2 model: shape checks and sparse-variant equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, plans, pruning

SPEC = model.ModelSpec(d_model=32, n_heads=2, d_ff=64, n_layers=1, n_classes=4,
                       sparsity=0.6, granularity=8)


def _build(variant, spec=SPEC, seed=3):
    params = model.init_params(seed, spec)
    pruned = model.prune_params(params, spec, variant)
    args = model.flatten_args(params, spec, variant, pruned)
    apply_fn = model.make_apply(spec, variant, block_m=16)
    return params, pruned, args, apply_fn


class TestShapes:
    @pytest.mark.parametrize("variant", ["dense", "tw", "tvw"])
    def test_output_shape(self, rng, variant):
        _, _, args, apply_fn = _build(variant)
        x = jnp.asarray(rng.normal(size=(2, 8, SPEC.d_model)).astype(np.float32))
        out = apply_fn(x, *[jnp.asarray(a) for _, a in args])
        assert out.shape == (2, SPEC.n_classes)
        assert np.isfinite(np.asarray(out)).all()

    def test_matmul_defs_cover_layers(self):
        spec = model.ModelSpec(n_layers=3)
        defs = model.matmul_defs(spec)
        assert len(defs) == 12
        assert defs[0][0] == "layer0/wqkv"
        assert defs[-1][0] == "layer2/w2"

    def test_flatten_order_is_stable(self):
        params, pruned, args, _ = _build("tw")
        names = [n for n, _ in args]
        assert names[0] == "layer0/wqkv/b_cond"
        assert names[-1] == "head"


class TestSparseEquivalence:
    """The sparse variants must equal the dense model evaluated with the
    masked weights — the pattern changes *which* weights survive, never the
    arithmetic."""

    @pytest.mark.parametrize("variant", ["tw", "tvw"])
    def test_variant_equals_masked_dense(self, rng, variant):
        params, pruned, args, apply_fn = _build(variant)
        x = jnp.asarray(rng.normal(size=(2, 8, SPEC.d_model)).astype(np.float32))
        got = apply_fn(x, *[jnp.asarray(a) for _, a in args])

        # dense model with masked weights
        masked = dict(params)
        for name, _, _ in model.matmul_defs(SPEC):
            p = pruned[name]
            masked[name] = (
                plans.decode_tw(p) if variant == "tw" else plans.decode_tvw(p)
            )
        dense_args = model.flatten_args(masked, SPEC, "dense", {})
        dense_fn = model.make_apply(SPEC, "dense")
        want = dense_fn(x, *[jnp.asarray(a) for _, a in dense_args])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)

    def test_dense_variant_matches_pure_jnp(self, rng):
        """The dense variant's Pallas matmuls agree with jnp.matmul end to end."""
        params, _, args, apply_fn = _build("dense")
        x = jnp.asarray(rng.normal(size=(2, 8, SPEC.d_model)).astype(np.float32))
        got = apply_fn(x, *[jnp.asarray(a) for _, a in args])

        # independent jnp-only reimplementation
        def ln(h, scale, bias):
            mu = h.mean(-1, keepdims=True)
            var = h.var(-1, keepdims=True)
            return (h - mu) / jnp.sqrt(var + 1e-5) * scale + bias

        h = x
        b, s, d = x.shape
        nh, dh = SPEC.n_heads, SPEC.d_model // SPEC.n_heads
        p = params
        qkv = h.reshape(b * s, d) @ p["layer0/wqkv"]
        q, k_, v = jnp.split(qkv.reshape(b, s, 3 * d), 3, axis=-1)
        q = q.reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
        k_ = k_.reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
        v = v.reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
        attn = jax.nn.softmax(jnp.einsum("bhqd,bhkd->bhqk", q, k_) / np.sqrt(dh), -1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", attn, v).transpose(0, 2, 1, 3).reshape(b * s, d)
        h = ln(h + (ctx @ p["layer0/wo"]).reshape(b, s, d),
               p["layer0/ln1/scale"], p["layer0/ln1/bias"])
        ff = jax.nn.gelu(h.reshape(b * s, d) @ p["layer0/w1"]) @ p["layer0/w2"]
        h = ln(h + ff.reshape(b, s, d), p["layer0/ln2/scale"], p["layer0/ln2/bias"])
        want = h.mean(axis=1) @ p["head"]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


class TestPruneParams:
    def test_sparsity_applied_per_weight(self):
        params, pruned, _, _ = _build("tw")
        for name, _, _ in model.matmul_defs(SPEC):
            assert abs(pruned[name].row_len.sum() * pruned[name].g /
                       (pruned[name].k * pruned[name].n) - (1 - SPEC.sparsity)) < 0.15

    def test_dense_variant_has_no_plans(self):
        params = model.init_params(0, SPEC)
        assert model.prune_params(params, SPEC, "dense") == {}

    def test_unknown_variant_raises(self):
        params = model.init_params(0, SPEC)
        with pytest.raises(ValueError):
            model.prune_params(params, SPEC, "banana")
