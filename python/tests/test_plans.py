"""CTO plan encode/decode round-trips."""

import numpy as np
import pytest

from compile import plans, pruning


class TestTwPlan:
    def test_roundtrip(self, rng):
        w = rng.normal(size=(96, 80)).astype(np.float32)
        tw = pruning.prune_tw(w, 0.6, g=16)
        plan = plans.encode_tw(w, tw)
        np.testing.assert_allclose(plans.decode_tw(plan), w * tw.mask())

    def test_padding_invariants(self, rng):
        w = rng.normal(size=(64, 48)).astype(np.float32)
        tw = pruning.prune_tw(w, 0.5, g=16)
        plan = plans.encode_tw(w, tw)
        assert plan.kmax % 8 == 0
        for t in range(plan.num_tiles):
            kt = int(plan.row_len[t])
            # padded rows are zero-valued
            assert (plan.b_cond[t, kt:, :] == 0).all()
            # padded row indices are in-range (they index row 0)
            assert (plan.row_idx[t] < plan.k).all()
            # padded columns carry the sentinel N
            width = (plan.col_idx[t] < plan.n).sum()
            assert (plan.col_idx[t, width:] == plan.n).all()

    def test_flops_accounting(self, rng):
        w = rng.normal(size=(64, 64)).astype(np.float32)
        tw = pruning.prune_tw(w, 0.75, g=16)
        plan = plans.encode_tw(w, tw)
        m = 32
        assert plan.flops(m) < plan.dense_flops(m)
        # condensed flops == 2*M*G*sum(row_len)
        assert plan.flops(m) == 2 * m * plan.g * int(plan.row_len.sum())

    def test_col_idx_covers_kept_cols(self, rng):
        w = rng.normal(size=(64, 64)).astype(np.float32)
        tw = pruning.prune_tw(w, 0.5, g=16)
        plan = plans.encode_tw(w, tw)
        valid = plan.col_idx[plan.col_idx < plan.n]
        assert sorted(valid.tolist()) == sorted(tw.kept_cols.tolist())


class TestVw24Plan:
    def test_roundtrip(self, rng):
        w = rng.normal(size=(64, 48)).astype(np.float32)
        mask = pruning.prune_vw(w, 0.5, 4)
        plan = plans.encode_vw24(w, mask)
        np.testing.assert_allclose(plans.decode_vw24(plan), w * mask)

    def test_storage_is_half(self, rng):
        w = rng.normal(size=(64, 32)).astype(np.float32)
        plan = plans.encode_vw24(w, pruning.prune_vw(w, 0.5, 4))
        assert plan.b_vals.shape == (32, 32)
        assert plan.b_sel.shape == (32, 32)
        assert plan.b_sel.min() >= 0 and plan.b_sel.max() <= 3

    def test_rejects_non_24_mask(self, rng):
        w = rng.normal(size=(8, 4)).astype(np.float32)
        bad = np.ones((8, 4), dtype=bool)
        with pytest.raises(ValueError):
            plans.encode_vw24(w, bad)

    def test_sel_strictly_increasing_in_group(self, rng):
        w = rng.normal(size=(64, 16)).astype(np.float32)
        plan = plans.encode_vw24(w, pruning.prune_vw(w, 0.5, 4))
        sel = plan.b_sel.reshape(16, 2, 16)
        assert (sel[:, 1, :] > sel[:, 0, :]).all()


class TestTvwPlan:
    def test_roundtrip(self, rng):
        w = rng.normal(size=(96, 80)).astype(np.float32)
        tw, mask = pruning.prune_tvw(w, 0.7, g=16)
        plan = plans.encode_tvw(w, tw, mask)
        np.testing.assert_allclose(plans.decode_tvw(plan), w * mask)

    def test_storage_halves_kmax(self, rng):
        w = rng.normal(size=(64, 64)).astype(np.float32)
        tw, mask = pruning.prune_tvw(w, 0.75, g=16)
        plan = plans.encode_tvw(w, tw, mask)
        assert plan.b_vals.shape[1] * 2 == plan.kmax
        assert plan.kmax % 8 == 0

    def test_flops_half_of_tw(self, rng):
        w = rng.normal(size=(64, 64)).astype(np.float32)
        tw, mask = pruning.prune_tvw(w, 0.75, g=16)
        plan = plans.encode_tvw(w, tw, mask)
        base = plans.encode_tw(w, tw)
        assert plan.flops(32) * 2 == base.flops(32)
