"""Property-based shape/sparsity sweeps of the Pallas kernels (hypothesis)."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import plans, pruning
from compile.kernels import dense_matmul, tw_matmul, tvw_matmul, vw24_matmul

TOL = dict(rtol=2e-4, atol=2e-4)
COMMON = dict(max_examples=20, deadline=None)

dims = st.integers(min_value=1, max_value=96)
dims4 = st.integers(min_value=1, max_value=24).map(lambda x: x * 4)
dims8 = st.integers(min_value=1, max_value=12).map(lambda x: x * 8)


def _rand(shape, seed):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@settings(**COMMON)
@given(m=dims, k=dims, n=dims, bm=st.sampled_from([8, 16, 32, 128]), seed=st.integers(0, 2**16))
def test_dense_any_shape(m, k, n, bm, seed):
    a, w = _rand((m, k), seed), _rand((k, n), seed + 1)
    got = dense_matmul(jnp.asarray(a), jnp.asarray(w), block=(bm, bm, bm))
    np.testing.assert_allclose(np.asarray(got), a @ w, **TOL)


@settings(**COMMON)
@given(
    m=dims,
    k=dims8,
    n=dims,
    g=st.sampled_from([8, 16, 32]),
    s=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**16),
)
def test_tw_any_shape_any_sparsity(m, k, n, g, s, seed):
    a, w = _rand((m, k), seed), _rand((k, n), seed + 1)
    tw = pruning.prune_tw(w, s, g=g)
    p = plans.encode_tw(w, tw)
    got = tw_matmul(
        jnp.asarray(a), jnp.asarray(p.b_cond), jnp.asarray(p.row_idx),
        jnp.asarray(p.col_idx), n=p.n, block_m=16,
    )
    np.testing.assert_allclose(np.asarray(got), a @ (w * tw.mask()), **TOL)


@settings(**COMMON)
@given(m=dims, k=dims4, n=dims, seed=st.integers(0, 2**16))
def test_vw24_any_shape(m, k, n, seed):
    a, w = _rand((m, k), seed), _rand((k, n), seed + 1)
    mask = pruning.prune_vw(w, 0.5, 4)
    p = plans.encode_vw24(w, mask)
    got = vw24_matmul(jnp.asarray(a), jnp.asarray(p.b_vals), jnp.asarray(p.b_sel), block=(16, 16))
    np.testing.assert_allclose(np.asarray(got), a @ (w * mask), **TOL)


@settings(**COMMON)
@given(
    m=dims,
    k=dims8,
    n=dims,
    g=st.sampled_from([8, 16]),
    s=st.floats(0.5, 0.9),
    seed=st.integers(0, 2**16),
)
def test_tvw_any_shape_any_sparsity(m, k, n, g, s, seed):
    a, w = _rand((m, k), seed), _rand((k, n), seed + 1)
    tw, mask = pruning.prune_tvw(w, s, g=g)
    p = plans.encode_tvw(w, tw, mask)
    got = tvw_matmul(
        jnp.asarray(a), jnp.asarray(p.b_vals), jnp.asarray(p.b_sel),
        jnp.asarray(p.row_idx), jnp.asarray(p.col_idx), n=p.n, block_m=16,
    )
    np.testing.assert_allclose(np.asarray(got), a @ (w * mask), **TOL)


@settings(**COMMON)
@given(
    k=dims8, n=dims, g=st.sampled_from([8, 16]),
    s=st.floats(0.0, 0.95), seed=st.integers(0, 2**16),
)
def test_tw_plan_roundtrip_property(k, n, g, s, seed):
    w = _rand((k, n), seed)
    tw = pruning.prune_tw(w, s, g=g)
    p = plans.encode_tw(w, tw)
    np.testing.assert_allclose(plans.decode_tw(p), w * tw.mask())


@settings(**COMMON)
@given(k=dims8, n=dims, s=st.floats(0.5, 0.95), g=st.sampled_from([8, 16]), seed=st.integers(0, 2**16))
def test_tvw_plan_roundtrip_property(k, n, s, g, seed):
    w = _rand((k, n), seed)
    tw, mask = pruning.prune_tvw(w, s, g=g)
    p = plans.encode_tvw(w, tw, mask)
    np.testing.assert_allclose(plans.decode_tvw(p), w * mask)
