"""Pallas kernels vs pure-jnp oracles — the core correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import plans, pruning
from compile.kernels import (
    dense_matmul,
    ref,
    tw_matmul,
    tw_matmul_tiles,
    tvw_matmul,
    vw24_matmul,
)

TOL = dict(rtol=1e-4, atol=1e-4)


def _mats(rng, m, k, n):
    a = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    return jnp.asarray(a), w


class TestDense:
    @pytest.mark.parametrize("shape", [(32, 32, 32), (40, 96, 80), (128, 256, 64), (1, 8, 8)])
    def test_vs_ref(self, rng, shape):
        m, k, n = shape
        a, w = _mats(rng, m, k, n)
        got = dense_matmul(a, jnp.asarray(w), block=(32, 32, 32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref.ref_dense(a, w)), **TOL)

    def test_non_divisible_blocks(self, rng):
        a, w = _mats(rng, 50, 70, 30)
        got = dense_matmul(a, jnp.asarray(w), block=(16, 16, 16))
        np.testing.assert_allclose(np.asarray(got), np.asarray(a) @ w, **TOL)

    def test_block_larger_than_matrix(self, rng):
        a, w = _mats(rng, 8, 8, 8)
        got = dense_matmul(a, jnp.asarray(w), block=(128, 128, 128))
        np.testing.assert_allclose(np.asarray(got), np.asarray(a) @ w, **TOL)


class TestTW:
    @pytest.mark.parametrize("s", [0.25, 0.5, 0.75])
    @pytest.mark.parametrize("g", [16, 32])
    def test_vs_mask_oracle(self, rng, s, g):
        a, w = _mats(rng, 40, 96, 80)
        tw = pruning.prune_tw(w, s, g=g)
        p = plans.encode_tw(w, tw)
        got = tw_matmul(
            a, jnp.asarray(p.b_cond), jnp.asarray(p.row_idx), jnp.asarray(p.col_idx),
            n=p.n, block_m=16,
        )
        want = np.asarray(a) @ (w * tw.mask())
        np.testing.assert_allclose(np.asarray(got), want, **TOL)

    def test_vs_condensed_ref(self, rng):
        a, w = _mats(rng, 32, 64, 64)
        tw = pruning.prune_tw(w, 0.6, g=16)
        p = plans.encode_tw(w, tw)
        args = (jnp.asarray(p.b_cond), jnp.asarray(p.row_idx), jnp.asarray(p.col_idx))
        got = tw_matmul(a, *args, n=p.n, block_m=16)
        want = ref.ref_tw_condensed(a, *args, p.n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)

    def test_tiles_shape(self, rng):
        a, w = _mats(rng, 24, 32, 48)
        tw = pruning.prune_tw(w, 0.5, g=16)
        p = plans.encode_tw(w, tw)
        cc = tw_matmul_tiles(a, jnp.asarray(p.b_cond), jnp.asarray(p.row_idx), block_m=8)
        assert cc.shape == (p.num_tiles, 24, p.g)

    def test_pruned_columns_are_zero(self, rng):
        a, w = _mats(rng, 16, 32, 32)
        tw = pruning.prune_tw(w, 0.7, g=8)
        p = plans.encode_tw(w, tw)
        got = np.asarray(
            tw_matmul(a, jnp.asarray(p.b_cond), jnp.asarray(p.row_idx),
                      jnp.asarray(p.col_idx), n=p.n, block_m=8)
        )
        pruned_cols = sorted(set(range(p.n)) - set(tw.kept_cols.tolist()))
        assert (got[:, pruned_cols] == 0).all()


class TestVW24:
    @pytest.mark.parametrize("shape", [(32, 64, 48), (40, 128, 80), (8, 8, 8)])
    def test_vs_mask_oracle(self, rng, shape):
        m, k, n = shape
        a, w = _mats(rng, m, k, n)
        mask = pruning.prune_vw(w, 0.5, 4)
        p = plans.encode_vw24(w, mask)
        got = vw24_matmul(a, jnp.asarray(p.b_vals), jnp.asarray(p.b_sel), block=(16, 16))
        np.testing.assert_allclose(np.asarray(got), np.asarray(a) @ (w * mask), **TOL)

    def test_vs_decode_ref(self, rng):
        a, w = _mats(rng, 16, 32, 32)
        p = plans.encode_vw24(w, pruning.prune_vw(w, 0.5, 4))
        got = vw24_matmul(a, jnp.asarray(p.b_vals), jnp.asarray(p.b_sel), block=(8, 8))
        want = ref.ref_vw24(a, jnp.asarray(p.b_vals), jnp.asarray(p.b_sel))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


class TestTVW:
    @pytest.mark.parametrize("s", [0.5, 0.7, 0.875])
    def test_vs_mask_oracle(self, rng, s):
        a, w = _mats(rng, 40, 96, 80)
        tw, mask = pruning.prune_tvw(w, s, g=16)
        p = plans.encode_tvw(w, tw, mask)
        got = tvw_matmul(
            a, jnp.asarray(p.b_vals), jnp.asarray(p.b_sel),
            jnp.asarray(p.row_idx), jnp.asarray(p.col_idx), n=p.n, block_m=16,
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(a) @ (w * mask), **TOL)

    def test_vs_condensed_ref(self, rng):
        a, w = _mats(rng, 24, 64, 64)
        tw, mask = pruning.prune_tvw(w, 0.75, g=16)
        p = plans.encode_tvw(w, tw, mask)
        args = (
            jnp.asarray(p.b_vals), jnp.asarray(p.b_sel),
            jnp.asarray(p.row_idx), jnp.asarray(p.col_idx),
        )
        got = tvw_matmul(a, *args, n=p.n, block_m=8)
        want = ref.ref_tvw_condensed(a, *args, p.n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


class TestTEW:
    def test_ref_tew_equals_mask_oracle(self, rng):
        a, w = _mats(rng, 32, 64, 64)
        tw, remedy = pruning.prune_tew(w, 0.6, 0.05, g=16)
        p = plans.encode_tw(w, tw)
        rr, cc = np.nonzero(remedy)
        got = ref.ref_tew(
            a, jnp.asarray(p.b_cond), jnp.asarray(p.row_idx), jnp.asarray(p.col_idx),
            p.n,
            jnp.asarray(w[rr, cc]), jnp.asarray(rr.astype(np.int32)),
            jnp.asarray(cc.astype(np.int32)),
        )
        want = np.asarray(a) @ (w * (tw.mask() | remedy))
        np.testing.assert_allclose(np.asarray(got), want, **TOL)

    def test_tew_kernel_composition(self, rng):
        """TEW executes as TW kernel + COO remainder (linearity, §III-A)."""
        a, w = _mats(rng, 16, 32, 32)
        tw, remedy = pruning.prune_tew(w, 0.5, 0.03, g=8)
        p = plans.encode_tw(w, tw)
        c_tw = np.asarray(
            tw_matmul(a, jnp.asarray(p.b_cond), jnp.asarray(p.row_idx),
                      jnp.asarray(p.col_idx), n=p.n, block_m=8)
        )
        c_rem = np.asarray(a) @ (w * remedy)
        want = np.asarray(a) @ (w * (tw.mask() | remedy))
        np.testing.assert_allclose(c_tw + c_rem, want, **TOL)
