"""Invariants of the pruning algorithms (Alg. 1-3)."""

import numpy as np
import pytest

from compile import pruning


class TestEW:
    def test_target_sparsity(self, rng):
        w = rng.normal(size=(64, 96)).astype(np.float32)
        for s in (0.1, 0.5, 0.75, 0.9):
            mask = pruning.prune_ew(w, s)
            assert abs((1 - mask.mean()) - s) < 1e-3

    def test_keeps_largest(self, rng):
        w = rng.normal(size=(32, 32)).astype(np.float32)
        mask = pruning.prune_ew(w, 0.5)
        kept_min = np.abs(w[mask]).min()
        pruned_max = np.abs(w[~mask]).max()
        assert kept_min >= pruned_max

    def test_taylor_score(self, rng):
        w = rng.normal(size=(16, 16)).astype(np.float32)
        g = rng.normal(size=(16, 16)).astype(np.float32)
        mask = pruning.prune_ew(w, 0.5, grad=g)
        kept_min = np.abs((w * g)[mask]).min()
        pruned_max = np.abs((w * g)[~mask]).max()
        assert kept_min >= pruned_max

    def test_extremes(self, rng):
        w = rng.normal(size=(8, 8)).astype(np.float32)
        assert pruning.prune_ew(w, 0.0).all()
        assert not pruning.prune_ew(w, 1.0).any()


class TestVW:
    def test_24_balance(self, rng):
        w = rng.normal(size=(64, 48)).astype(np.float32)
        mask = pruning.prune_vw(w, 0.5, 4)
        groups = mask.reshape(16, 4, 48)
        assert (groups.sum(axis=1) == 2).all()

    def test_416(self, rng):
        w = rng.normal(size=(64, 32)).astype(np.float32)
        mask = pruning.prune_vw(w, 0.75, 16)
        groups = mask.reshape(4, 16, 32)
        assert (groups.sum(axis=1) == 4).all()

    def test_keeps_largest_in_vector(self, rng):
        w = rng.normal(size=(8, 4)).astype(np.float32)
        mask = pruning.prune_vw(w, 0.5, 4)
        for col in range(4):
            for grp in range(2):
                vec = np.abs(w[grp * 4 : grp * 4 + 4, col])
                kept = vec[mask[grp * 4 : grp * 4 + 4, col]]
                assert kept.min() >= np.median(vec)

    def test_indivisible_k_raises(self, rng):
        w = rng.normal(size=(10, 4)).astype(np.float32)
        with pytest.raises(ValueError):
            pruning.prune_vw(w, 0.5, 4)


class TestBW:
    def test_block_structure(self, rng):
        w = rng.normal(size=(64, 64)).astype(np.float32)
        mask = pruning.prune_bw(w, 0.5, 16)
        blocks = mask.reshape(4, 16, 4, 16)
        per_block = blocks.sum(axis=(1, 3))
        assert set(np.unique(per_block)) <= {0, 256}

    def test_target_sparsity(self, rng):
        w = rng.normal(size=(64, 64)).astype(np.float32)
        mask = pruning.prune_bw(w, 0.75, 16)
        assert abs((1 - mask.mean()) - 0.75) < 0.1

    def test_ragged_edges(self, rng):
        w = rng.normal(size=(70, 50)).astype(np.float32)
        mask = pruning.prune_bw(w, 0.5, 16)
        assert mask.shape == (70, 50)
        assert 0.3 < (1 - mask.mean()) < 0.7


class TestTW:
    @pytest.mark.parametrize("s", [0.25, 0.5, 0.75])
    @pytest.mark.parametrize("g", [16, 32, 64])
    def test_target_sparsity(self, rng, s, g):
        w = rng.normal(size=(256, 256)).astype(np.float32)
        tw = pruning.prune_tw(w, s, g=g)
        assert abs(tw.sparsity() - s) < 0.03

    def test_structure_consistency(self, rng):
        w = rng.normal(size=(96, 80)).astype(np.float32)
        tw = pruning.prune_tw(w, 0.6, g=16)
        # kept columns sorted and unique
        assert (np.diff(tw.kept_cols) > 0).all()
        # every tile keeps at least one row (condense invariant)
        assert all(len(r) >= 1 for r in tw.tile_rows)
        # tile rows sorted
        for r in tw.tile_rows:
            assert (np.diff(r) > 0).all() or len(r) <= 1
        # mask sparsity agrees with structure sparsity
        assert abs((1 - tw.mask().mean()) - tw.sparsity()) < 1e-9

    def test_mask_is_tile_structured(self, rng):
        """Inside every tile, the mask must be the outer product of a row
        indicator and a column indicator (whole rows/cols pruned)."""
        w = rng.normal(size=(64, 64)).astype(np.float32)
        tw = pruning.prune_tw(w, 0.5, g=16)
        m = tw.mask()
        for t in range(tw.num_tiles):
            cols = tw.tile_cols(t)
            sub = m[:, cols]
            rows_on = sub.any(axis=1)
            cols_on = sub.any(axis=0)
            assert (sub == np.outer(rows_on, cols_on)).all()

    def test_g_equal_n_is_global_structural(self, rng):
        """G == N degenerates to global row/column pruning (paper §I)."""
        w = rng.normal(size=(32, 32)).astype(np.float32)
        tw = pruning.prune_tw(w, 0.5, g=32)
        assert tw.num_tiles == 1

    def test_col_sparsity_override(self, rng):
        w = rng.normal(size=(128, 128)).astype(np.float32)
        tw = pruning.prune_tw(w, 0.75, g=32, col_sparsity=0.5)
        assert len(tw.kept_cols) == 64
        assert abs(tw.sparsity() - 0.75) < 0.05


class TestTEW:
    def test_remedy_disjoint_and_sized(self, rng):
        w = rng.normal(size=(96, 96)).astype(np.float32)
        tw, remedy = pruning.prune_tew(w, 0.7, 0.05, g=16)
        assert not (tw.mask() & remedy).any()
        assert abs(remedy.mean() - 0.05) < 0.01

    def test_final_sparsity(self, rng):
        w = rng.normal(size=(128, 128)).astype(np.float32)
        tw, remedy = pruning.prune_tew(w, 0.7, 0.05, g=32)
        final = tw.mask() | remedy
        assert abs((1 - final.mean()) - 0.7) < 0.03

    def test_remedy_picks_highest_pruned(self, rng):
        w = rng.normal(size=(64, 64)).astype(np.float32)
        tw, remedy = pruning.prune_tew(w, 0.6, 0.03, g=16)
        pruned = ~(tw.mask() | remedy)
        if remedy.any() and pruned.any():
            assert np.abs(w[remedy]).min() >= np.abs(w[pruned]).max() - 1e-6


class TestTVW:
    def test_24_inside_tiles(self, rng):
        w = rng.normal(size=(128, 128)).astype(np.float32)
        tw, mask = pruning.prune_tvw(w, 0.75, g=32)
        for t in range(tw.num_tiles):
            rows, cols = tw.tile_rows[t], tw.tile_cols(t)
            sub = mask[np.ix_(rows, cols)]
            kt = sub.shape[0]
            pad = (-kt) % 4
            padded = np.vstack([sub, np.zeros((pad, sub.shape[1]), dtype=bool)])
            per_group = padded.reshape(-1, 4, sub.shape[1]).sum(axis=1)
            assert (per_group <= 2).all()

    def test_floor_is_half(self, rng):
        w = rng.normal(size=(64, 64)).astype(np.float32)
        with pytest.raises(ValueError):
            pruning.prune_tvw(w, 0.3, g=16)

    def test_sparsity_at_half_is_pure_vw(self, rng):
        """At s=0.5 TVW degenerates to plain 2:4 over the whole matrix."""
        w = rng.normal(size=(64, 64)).astype(np.float32)
        tw, mask = pruning.prune_tvw(w, 0.5, g=16)
        assert len(tw.kept_cols) == 64
        assert abs((1 - mask.mean()) - 0.5) < 0.02

    def test_target_sparsity(self, rng):
        w = rng.normal(size=(256, 256)).astype(np.float32)
        for s in (0.5, 0.625, 0.75, 0.875):
            _, mask = pruning.prune_tvw(w, s, g=64)
            assert abs((1 - mask.mean()) - s) < 0.02

    def test_mask_subset_of_tw(self, rng):
        w = rng.normal(size=(64, 64)).astype(np.float32)
        tw, mask = pruning.prune_tvw(w, 0.75, g=16)
        assert not (mask & ~tw.mask()).any()


class TestMultiStage:
    def test_monotone_sparsity(self, rng):
        w = rng.normal(size=(64, 64)).astype(np.float32)
        seen = []

        def prune_fn(w_, s_t):
            seen.append(s_t)
            return pruning.prune_ew(w_, s_t)

        final, _ = pruning.multi_stage_prune(w, 0.75, 0.25, prune_fn)
        assert seen == [0.25, 0.5, 0.75]
        assert abs((final == 0).mean() - 0.75) < 0.02

    def test_fine_tune_hook_called(self, rng):
        w = rng.normal(size=(32, 32)).astype(np.float32)
        calls = []

        def ft(w_, mask):
            calls.append(mask.mean())
            return w_ * 1.01  # pretend-finetune

        pruning.multi_stage_prune(w, 0.5, 0.25, lambda w_, s: pruning.prune_ew(w_, s), ft)
        assert len(calls) == 2

    def test_tw_multi_stage(self, rng):
        w = rng.normal(size=(64, 64)).astype(np.float32)
        final, tw = pruning.multi_stage_prune(
            w, 0.75, 0.25, lambda w_, s: pruning.prune_tw(w_, s, g=16)
        )
        assert isinstance(tw, pruning.TwStructure)
        assert abs(tw.sparsity() - 0.75) < 0.05
