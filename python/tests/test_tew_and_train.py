"""TEW kernel composition + the train-step lowering."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, plans, pruning
from compile.kernels.tew_gemm import encode_remedy_coo, tew_matmul

TOL = dict(rtol=1e-4, atol=1e-4)


class TestTewKernel:
    def test_vs_mask_oracle(self, rng):
        m, k, n = 32, 64, 64
        a = rng.normal(size=(m, k)).astype(np.float32)
        w = rng.normal(size=(k, n)).astype(np.float32)
        tw, remedy = pruning.prune_tew(w, 0.6, 0.05, g=16)
        p = plans.encode_tw(w, tw)
        vals, rows, cols = encode_remedy_coo(w, remedy, 256)
        got = tew_matmul(
            jnp.asarray(a), jnp.asarray(p.b_cond), jnp.asarray(p.row_idx),
            jnp.asarray(p.col_idx), jnp.asarray(vals), jnp.asarray(rows),
            jnp.asarray(cols), n=n, block_m=16,
        )
        want = a @ (w * (tw.mask() | remedy))
        np.testing.assert_allclose(np.asarray(got), want, **TOL)

    def test_padding_entries_dropped(self, rng):
        m, k, n = 8, 16, 16
        a = rng.normal(size=(m, k)).astype(np.float32)
        w = rng.normal(size=(k, n)).astype(np.float32)
        tw = pruning.prune_tw(w, 0.5, g=8)
        p = plans.encode_tw(w, tw)
        # all-padding remainder: must equal plain TW
        vals = np.zeros(64, dtype=np.float32)
        rows = np.zeros(64, dtype=np.int32)
        cols = np.full(64, n, dtype=np.int32)
        got = tew_matmul(
            jnp.asarray(a), jnp.asarray(p.b_cond), jnp.asarray(p.row_idx),
            jnp.asarray(p.col_idx), jnp.asarray(vals), jnp.asarray(rows),
            jnp.asarray(cols), n=n, block_m=8,
        )
        want = a @ (w * tw.mask())
        np.testing.assert_allclose(np.asarray(got), want, **TOL)

    def test_encode_rejects_overflow(self, rng):
        w = rng.normal(size=(16, 16)).astype(np.float32)
        remedy = np.ones((16, 16), dtype=bool)
        with pytest.raises(ValueError):
            encode_remedy_coo(w, remedy, 4)


SPEC = model.ModelSpec(d_model=32, n_heads=2, d_ff=64, n_layers=1, n_classes=4)


class TestTrainStep:
    def _setup(self, rng):
        params = model.init_params(3, SPEC)
        args = model.flatten_args(params, SPEC, "dense", {})
        x = jnp.asarray(rng.normal(size=(4, 8, SPEC.d_model)).astype(np.float32))
        y = jnp.asarray(np.array([0, 1, 2, 3], dtype=np.int32))
        tensors = [jnp.asarray(a) for _, a in args]
        return x, y, tensors

    def test_jnp_forward_matches_pallas(self, rng):
        params = model.init_params(3, SPEC)
        args = model.flatten_args(params, SPEC, "dense", {})
        t = [jnp.asarray(a) for _, a in args]
        x = jnp.asarray(rng.normal(size=(2, 8, SPEC.d_model)).astype(np.float32))
        ap = model.make_apply(SPEC, "dense")(x, *t)
        aj = model.make_apply_jnp(SPEC)(x, *t)
        np.testing.assert_allclose(np.asarray(ap), np.asarray(aj), rtol=1e-3, atol=1e-3)

    def test_loss_decreases(self, rng):
        x, y, tensors = self._setup(rng)
        step = model.make_train_step(SPEC)
        out = step(x, y, *tensors)
        l0 = float(out[0])
        for _ in range(25):
            out = step(x, y, *out[1:])
        assert float(out[0]) < l0

    def test_output_arity_and_shapes(self, rng):
        x, y, tensors = self._setup(rng)
        step = model.make_train_step(SPEC)
        out = step(x, y, *tensors)
        assert len(out) == len(tensors) + 1
        assert out[0].shape == ()
        for o, t in zip(out[1:], tensors):
            assert o.shape == t.shape

    def test_masked_params_stay_learnable(self, rng):
        """Zeroed weights receive gradients (the driver re-masks each step);
        the step itself must not NaN on sparse params."""
        x, y, tensors = self._setup(rng)
        tensors[0] = tensors[0].at[:, ::2].set(0.0)
        step = model.make_train_step(SPEC)
        out = step(x, y, *tensors)
        assert np.isfinite(float(out[0]))
